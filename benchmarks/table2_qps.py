"""Table 2 / Fig. 4: best QPS at ≥80% recall (k=10, CPU-scaled corpus).

Every registered first-stage backend runs through the SAME unified
pool → candidates → rerank pipeline (``LemurRetriever.search``) over the
same trained LEMUR reduction; token-level baselines (muvera, dessert,
token_pruning) simply ignore the latent side of the query batch.  Each
backend gets a hyperparameter grid-search — a list of typed
``SearchParams`` — and we report its fastest configuration clearing the
recall bar (the paper's Pareto protocol), plus the exact-MaxSim latency
ceiling.  The facade compiles one query fn per SearchParams, so ``timeit``
measures steady-state latency by construction.

``run(backends=[...])`` restricts the sweep (wired to
``benchmarks/run.py --backend``); per-backend rows are also written to
``results/bench_table2_<backend>.json`` so the perf trajectory tracks each
backend separately."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks import common
from repro.anns import registry
from repro.core import maxsim, recall_at
from repro.retriever import IVFSearchParams, SearchParams, TokenPruningSearchParams

RECALL_BAR = 0.8

# per-backend query-time grids: typed SearchParams; backends without
# per-call knobs beyond k' (the shared rerank budget) sweep k' only
SWEEPS = {
    "ivf": [SearchParams(k_prime=kp, backend=IVFSearchParams(nprobe=n))
            for n in (8, 16, 32, 64) for kp in (50, 100, 200)],
    "bruteforce": [SearchParams(k_prime=kp) for kp in (50, 100, 200)],
    "muvera": [SearchParams(k_prime=kp) for kp in (50, 100, 200, 400)],
    "dessert": [SearchParams(k_prime=kp) for kp in (50, 100, 200, 400)],
    "token_pruning": [SearchParams(k_prime=kp,
                                   backend=TokenPruningSearchParams(nprobe=n))
                      for n in (2, 4, 8) for kp in (100, 200, 400)],
}


def _row_params(params: SearchParams) -> dict:
    """JSON-able row label for one grid point."""
    row = {"k_prime": params.k_prime}
    if params.backend is not None:
        row |= {k: v for k, v in dataclasses.asdict(params.backend).items()
                if v is not None}
    return row


def _best(rows):
    ok = [r for r in rows if r["recall"] >= RECALL_BAR]
    if not ok:
        return max(rows, key=lambda r: r["recall"]) | {"note": "recall bar missed"}
    return max(ok, key=lambda r: r["qps"])


def sweep_backend(name: str, q, qm, truth):
    """Grid-search one backend's SearchParams through the facade."""
    r = common.lemur_retriever(128, backend=name)
    rows = []
    for params in SWEEPS.get(name, [SearchParams(k_prime=kp)
                                    for kp in (50, 100, 200)]):
        t = common.timeit(lambda a, b, p=params: r.search(a, b, p), q, qm, iters=3)
        _, ids = r.search(q, qm, params)
        rows.append(_row_params(params)
                    | {"recall": float(recall_at(ids, truth).mean()),
                       "qps": q.shape[0] / t})
    return rows


def sweep_sharded(mesh_spec: str, q, qm, truth):
    """The sharded serving row: ``LemurRetriever.shard(mesh)`` (per-shard
    latent scan + rerank + hierarchical merge; the first stage is the exact
    scan, so the only query-time knob is the shared k' budget)."""
    from repro.launch.mesh import make_serving_mesh

    sr = common.lemur_retriever(128).shard(make_serving_mesh(mesh_spec))
    rows = []
    for params in (SearchParams(k_prime=kp) for kp in (50, 100, 200)):
        t = common.timeit(lambda a, b, p=params: sr.search(a, b, p), q, qm, iters=3)
        _, ids = sr.search(q, qm, params)
        rows.append(_row_params(params)
                    | {"recall": float(recall_at(ids, truth).mean()),
                       "qps": q.shape[0] / t})
    return rows


def serving_perf(sizes=(4096, 16384), *, batch: int = 32, d: int = 64,
                 nprobe: int = 16, k_prime: int = 128, td: int = 16,
                 emit_json: bool = True):
    """Fused-vs-legacy serving micro-bench -> repo-root ``BENCH_serving.json``.

    Times the two gather-dominated serving ops at each corpus size in
    ``sizes`` — the IVF probe scan (fp32 AND SQ8) and the candidate MaxSim
    rerank — through the real dispatch path (``use_fused_gather`` True vs
    False), asserting parity on every row (bit-identical ids on fp32,
    ≤2^-16-relative scores on SQ8): a CI bench-smoke run FAILS if the fused
    path ever diverges.  Indexes are built directly over random latents so
    the bench measures serving, not LEMUR training."""
    import jax.numpy as jnp
    import numpy as np

    from repro.anns import ivf as _ivf
    from repro.core import maxsim
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    for m in sizes:
        q = jnp.asarray(rng.standard_normal((batch, d)), jnp.float32)
        for sq8 in (False, True):
            index = _ivf.build_ivf(jax.random.PRNGKey(0),
                                   jnp.asarray(rng.standard_normal((m, d)),
                                               jnp.float32),
                                   sq8=sq8, kmeans_iters=3)
            npr = min(nprobe, index.nlist)
            legacy = jax.jit(lambda qq, idx=index, npr=npr: _ivf.search_ivf(
                idx, qq, npr, common.K, use_fused_gather=False))
            fused = jax.jit(lambda qq, idx=index, npr=npr: _ivf.search_ivf(
                idx, qq, npr, common.K, use_fused_gather=True))
            ls, li = legacy(q)
            fs, fi = fused(q)
            if sq8:
                fin = np.isfinite(np.asarray(ls))
                parity = bool(
                    np.array_equal(np.isfinite(np.asarray(fs)), fin)
                    and np.allclose(np.asarray(fs)[fin], np.asarray(ls)[fin],
                                    rtol=2 ** -14, atol=1e-4))
            else:
                parity = bool(np.array_equal(np.asarray(fi), np.asarray(li)))
            item = 1 if sq8 else 4
            gathered = batch * npr * index.capacity * (d * item + 4
                                                       + (4 if sq8 else 0))
            op = f"ivf_scan_{'sq8' if sq8 else 'fp32'}"
            rows.append(common.bench_row(
                op, f"m={m},B={batch},nprobe={npr},cap={index.capacity},d={d}",
                common.timeit(legacy, q, iters=3),
                common.timeit(fused, q, iters=3), gathered, parity=parity))
            common.emit(f"serving_{op}_m{m}", rows[-1]["fused_us"],
                        f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy")

        # candidate-gather rerank over random token matrices
        docs = jnp.asarray(rng.standard_normal((m, td, 32)), jnp.float32)
        dmask = jnp.asarray(rng.random((m, td)) > 0.2).at[:, 0].set(True)
        qt = jnp.asarray(rng.standard_normal((batch, 8, 32)), jnp.float32)
        qm = jnp.ones((batch, 8), bool)
        cand = jnp.asarray(rng.integers(0, m, (batch, k_prime)), jnp.int32)
        legacy = jax.jit(lambda a, b, c: maxsim.rerank(a, b, c, docs, dmask,
                                                       common.K))
        fused = jax.jit(lambda a, b, c: ops.fused_rerank(a, b, c, docs, dmask,
                                                         common.K))
        _, li = legacy(qt, qm, cand)
        _, fi = fused(qt, qm, cand)
        parity = bool(np.array_equal(np.asarray(fi), np.asarray(li)))
        gathered = batch * k_prime * td * (32 * 4 + 4)
        rows.append(common.bench_row(
            "rerank", f"m={m},B={batch},k_prime={k_prime},Td={td},d=32",
            common.timeit(legacy, qt, qm, cand, iters=3),
            common.timeit(fused, qt, qm, cand, iters=3), gathered,
            parity=parity))
        common.emit(f"serving_rerank_m{m}", rows[-1]["fused_us"],
                    f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy")

    out = {"meta": {"backend": jax.default_backend(), "batch": batch,
                    "sizes": list(sizes),
                    "note": "fused path == kernels/gather_scan.py dispatch; "
                            "on CPU both paths lower to jnp (ratio ~1); the "
                            "kernel wins land on TPU where the gather "
                            "never touches HBM"},
           "rows": rows}
    if emit_json:
        # preserve every section owned by the other serving benches
        # (serving_online.py's "online", serving_fleet.py's "replicated"/
        # "overload"): this bench owns only the top-level meta + rows
        prev = common.load_bench_root("serving")
        for section, body in prev.items():
            if section not in ("meta", "rows"):
                out[section] = body
        common.save_bench_root("serving", out)
    bad = [r["op"] for r in rows if not r["parity"]]
    if bad:
        raise SystemExit(f"fused-path parity regression in: {bad}")
    return out


def run(backends=None, mesh=None, emit_json: bool = False):
    if mesh:
        # must precede the first jax backend touch below
        import numpy as np

        from repro.launch.mesh import ensure_devices, parse_mesh_spec

        ensure_devices(int(np.prod(parse_mesh_spec(mesh))))
    q, qm = common.queries()
    truth = common.ground_truth()
    c = common.corpus()
    import jax.numpy as jnp

    docs = jnp.asarray(c.doc_tokens)
    mask = jnp.asarray(c.doc_mask)
    out = {}

    for name in backends or registry.list_backends():
        rows = sweep_backend(name, q, qm, truth)
        out[name] = _best(rows)
        common.save_json(f"table2_{name}", {"rows": rows, "best": out[name]})

    # exact MaxSim brute force (the latency ceiling)
    fn = jax.jit(lambda a, b: maxsim.true_topk(a, b, docs, mask, common.K))
    t = common.timeit(fn, q, qm, iters=3)
    out["exact_maxsim"] = {"recall": 1.0, "qps": q.shape[0] / t}

    if mesh:
        rows = sweep_sharded(mesh, q, qm, truth)
        out[f"sharded_{mesh}"] = _best(rows)
        common.save_json(f"table2_sharded_{mesh}", {"rows": rows,
                                                    "best": out[f"sharded_{mesh}"]})

    for name, r in out.items():
        common.emit(f"table2_{name}", 1e6 / max(r["qps"], 1e-9),
                    f"recall={r['recall']:.3f},qps={r['qps']:.0f}")
    common.save_json("table2_qps", out)
    if emit_json:
        serving_perf(emit_json=True)

    if "ivf" in out:
        baselines = [out[n]["qps"] for n in ("muvera", "token_pruning", "dessert")
                     if n in out]
        if baselines:
            common.emit("table2_speedup_vs_best_baseline", 0.0,
                        f"x{out['ivf']['qps'] / max(max(baselines), 1e-9):.1f}")
    return out


if __name__ == "__main__":
    import argparse

    _p = argparse.ArgumentParser()
    _p.add_argument("--backend", default=None,
                    help="comma list of backends, or 'all'")
    _p.add_argument("--mesh", default=None,
                    help="also report sharded QPS over this mesh, e.g. '1x8'")
    _p.add_argument("--emit-json", action="store_true",
                    help="also write repo-root BENCH_serving.json "
                         "(fused-vs-legacy serving rows)")
    _p.add_argument("--serving-only", action="store_true",
                    help="skip the backend sweeps; run ONLY the fused-vs-"
                         "legacy serving bench (the CI bench-smoke config)")
    _p.add_argument("--serving-sizes", default=None,
                    help="comma list of corpus sizes for the serving bench, "
                         "e.g. '768,1536'")
    _p.add_argument("--serving-batch", type=int, default=32,
                    help="query batch for the serving bench")
    _a = _p.parse_args()
    if _a.serving_only:
        _sizes = (tuple(int(s) for s in _a.serving_sizes.split(","))
                  if _a.serving_sizes else (4096, 16384))
        serving_perf(_sizes, batch=_a.serving_batch, emit_json=True)
    else:
        if _a.backend in (None, "all"):
            _backends = None  # run() defaults to the full registry
        else:
            _backends = [s for s in _a.backend.split(",") if s]
            for _n in _backends:
                registry.get_backend(_n)  # fail fast, before the corpus build
        run(backends=_backends, mesh=_a.mesh, emit_json=_a.emit_json)
