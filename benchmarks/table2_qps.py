"""Table 2 / Fig. 4: best QPS at ≥80% recall (k=10, CPU-scaled corpus) —
LEMUR vs MUVERA(+same ANNS/rerank) vs PLAID-style token pruning vs exact
MaxSim brute force.

Grid-searches each method's query hyperparameters and reports the fastest
configuration that clears the recall bar (the paper's Pareto protocol)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.anns import (
    MuveraConfig,
    build_ivf,
    build_token_pruning,
    doc_fde,
    query_fde,
    search_ivf,
    search_token_pruning,
)
from repro.core import maxsim, recall_at
from repro.core.index import query

RECALL_BAR = 0.8


def _best(rows):
    ok = [r for r in rows if r["recall"] >= RECALL_BAR]
    if not ok:
        return max(rows, key=lambda r: r["recall"]) | {"note": "recall bar missed"}
    return max(ok, key=lambda r: r["qps"])


def run():
    c = common.corpus()
    q, qm = common.queries()
    truth = common.ground_truth()
    docs = jnp.asarray(c.doc_tokens)
    mask = jnp.asarray(c.doc_mask)
    out = {}

    # --- LEMUR ---
    idx = common.lemur_index(128)
    rows = []
    for nprobe in (8, 16, 32, 64):
        for kp in (50, 100, 200):
            fn = jax.jit(lambda a, b, n=nprobe, k=kp: query(idx, a, b, k_prime=k,
                                                            use_ann=True, nprobe=n))
            t = common.timeit(fn, q, qm, iters=3)
            _, ids = fn(q, qm)
            rows.append({"nprobe": nprobe, "k_prime": kp,
                         "recall": float(recall_at(ids, truth).mean()),
                         "qps": q.shape[0] / t})
    out["lemur"] = _best(rows)

    # --- MUVERA (FDE + same IVF + same rerank) ---
    mcfg = MuveraConfig(r_reps=20, k_sim=5, final_dim=1280)
    dfde = doc_fde(docs, mask, mcfg)
    qfde = query_fde(q, qm, mcfg)
    fde_ivf = build_ivf(jax.random.PRNGKey(1), dfde, sq8=True)
    rows = []
    for nprobe in (8, 16, 32, 64):
        for kp in (50, 100, 200):
            def fn(qq, qqm, n=nprobe, k=kp):
                _, cand = search_ivf(fde_ivf, query_fde(qq, qqm, mcfg), n, k)
                return maxsim.rerank(qq, qqm, jnp.maximum(cand, 0), docs, mask, common.K)

            jfn = jax.jit(fn)
            t = common.timeit(jfn, q, qm, iters=3)
            _, ids = jfn(q, qm)
            rows.append({"nprobe": nprobe, "k_prime": kp,
                         "recall": float(recall_at(ids, truth).mean()),
                         "qps": q.shape[0] / t})
    out["muvera"] = _best(rows)

    # --- PLAID-style token pruning ---
    tp = build_token_pruning(jax.random.PRNGKey(2), docs, mask)
    rows = []
    for nprobe in (2, 4, 8):
        for kp in (100, 200, 400):
            def fn(qq, qqm, n=nprobe, k=kp):
                _, cand = search_token_pruning(tp, qq, qqm, nprobe=n, k_prime=k,
                                               m=common.M)
                return maxsim.rerank(qq, qqm, jnp.maximum(cand, 0), docs, mask, common.K)

            jfn = jax.jit(fn)
            t = common.timeit(jfn, q, qm, iters=3)
            _, ids = jfn(q, qm)
            rows.append({"nprobe": nprobe, "k_prime": kp,
                         "recall": float(recall_at(ids, truth).mean()),
                         "qps": q.shape[0] / t})
    out["token_pruning"] = _best(rows)

    # --- exact MaxSim brute force (the latency ceiling) ---
    fn = jax.jit(lambda a, b: maxsim.true_topk(a, b, docs, mask, common.K))
    t = common.timeit(fn, q, qm, iters=3)
    out["exact_maxsim"] = {"recall": 1.0, "qps": q.shape[0] / t}

    for name, r in out.items():
        common.emit(f"table2_{name}", 1e6 / max(r["qps"], 1e-9),
                    f"recall={r['recall']:.3f},qps={r['qps']:.0f}")
    common.save_json("table2_qps", out)

    lemur_qps = out["lemur"]["qps"]
    best_base = max(out["muvera"]["qps"], out["token_pruning"]["qps"])
    common.emit("table2_speedup_vs_best_baseline", 0.0,
                f"x{lemur_qps / max(best_base, 1e-9):.1f}")
    return out


if __name__ == "__main__":
    run()
