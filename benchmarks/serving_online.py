"""Online-serving benchmark: Poisson arrival replay through RetrieverServer.

Replays a seeded Poisson trace of ragged single queries against the online
runtime (``repro.serving``) in front of a LEMUR retriever, then EXTENDS the
repo-root ``BENCH_serving.json`` perf trail with latency-percentile rows —
the offline fused-vs-legacy rows written by ``table2_qps.serving_perf`` are
preserved; this bench owns the ``"online"`` section:

    {"meta": {...}, "rows": [...],            # offline (table2_qps)
     "online": {"meta": {...}, "rows": [      # this bench
        {"op": "online_serving", "p50_ms": ..., "p95_ms": ..., "p99_ms": ...,
         "qps": ..., "offered_qps": ..., "mean_occupancy": ...,
         "trace_count": ..., "compile_bound": ..., "parity": true}, ...]}}

Every run asserts the serving contract (SystemExit on violation, so the CI
bench-smoke job fails):

* **parity** — a sample of replayed requests is re-answered by a direct
  ``retriever.search`` of the raw ragged query; top-k ids must be
  bit-identical.
* **p99 finite** — percentiles must be real numbers (a deadlocked or
  request-dropping micro-batcher would poison them).
* **compile bound** — ``trace_count()`` never exceeds the bucket ladder's
  bound, no matter the trace's shape churn.

The ``"mutation"`` section (same merge-preserve contract) is the paged-
corpus trail: an add/delete/update churn loop under live traffic gating on
zero lost requests, monotone snapshot versions, ZERO new traces once the
pool is warm (the streaming-add bugfix contract), tombstoned docs never
surfacing — plus an ``add_amortization`` row comparing logical bytes moved
per added doc on the paged store against the flat ``jnp.concatenate``
layout it replaced (paged must be O(doc), not O(corpus)).

  PYTHONPATH=src python -m benchmarks.serving_online                # default
  PYTHONPATH=src python -m benchmarks.serving_online --m 600 --duration 10 \\
      --rate 50 --epochs 4                                          # CI smoke
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from benchmarks import common

LADDER = (8, 16, 32)


def run(m: int = 2000, *, d: int = 32, rate: float = 100.0,
        duration: float = 10.0, max_batch: int = 8, max_wait_us: int = 2000,
        backend: str = "ivf", epochs: int = 10, seed: int = 0,
        add_docs: int = 32, parity_sample: int = 16, churn_steps: int = 4,
        lifecycle: bool = False, emit_json: bool = True) -> dict:
    import jax

    from repro.core import LemurConfig
    from repro.data import synthetic
    from repro.retriever import IVFBackendConfig, LemurRetriever
    from repro.serving import (
        BucketLadder,
        RetrieverServer,
        poisson_trace,
        ragged_queries,
        replay,
        warm_buckets,
    )

    corpus = synthetic.make_corpus(m=m, d=d, avg_tokens=12, max_tokens=16,
                                   seed=seed)
    cfg = LemurConfig(d=d, d_prime=64, m_pretrain=min(256, m),
                      n_train=4096, n_ols=1024, epochs=epochs, k=10,
                      k_prime=min(128, m), anns=backend,
                      ivf=IVFBackendConfig(nprobe=16))
    retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(seed))
    ladder = BucketLadder(LADDER, max_batch=max_batch)
    queries = ragged_queries(256, d, tq_range=(2, 24), seed=seed + 1)
    arrivals = poisson_trace(rate, duration, seed=seed + 2)

    rows = []
    with RetrieverServer(retriever, ladder=ladder,
                         max_wait_us=max_wait_us) as srv:
        warmed = warm_buckets(retriever, ladder, d)
        results, report = replay(srv, queries, arrivals)

        # parity: a request sample re-answered by direct facade search
        rng = np.random.default_rng(seed + 3)
        sample = rng.choice(len(results), min(parity_sample, len(results)),
                            replace=False)
        # parity references run on a clone: private compile caches, so the
        # raw ragged-shape reference searches never pollute the server's
        # trace accounting (compiled fns now SURVIVE mutations, so
        # srv.trace_count() is cumulative across the whole run)
        ref = retriever.clone()
        parity = True
        for i in sample:
            q = queries[i % len(queries)]
            _, want = ref.search(q[None], np.ones((1, len(q)), bool))
            parity &= bool(np.array_equal(results[i][1], np.asarray(want)[0]))

        bound = ladder.compile_bound(1)
        rows.append({
            "op": "online_serving",
            "shape": (f"m={m},backend={backend},rate={rate:g},"
                      f"ladder={'/'.join(map(str, LADDER))},"
                      f"max_batch={ladder.max_batch},"
                      f"max_wait_us={max_wait_us}"),
            **{k: report[k] for k in ("p50_ms", "p95_ms", "p99_ms", "mean_ms",
                                      "qps", "offered_qps", "mean_occupancy",
                                      "n_requests", "n_batches")},
            "trace_count": report["trace_count"],
            "compile_bound": bound,
            "warmed_shapes": warmed,
            "parity": parity,
        })
        common.emit("serving_online_p99", rows[-1]["p99_ms"] * 1e3,
                    f"p50={rows[-1]['p50_ms']:.2f}ms,"
                    f"qps={rows[-1]['qps']:.0f},"
                    f"occ={rows[-1]['mean_occupancy']:.2f}")

        # add-while-serving: stream growth mid-replay, re-check parity on a
        # post-add query targeting a brand-new doc
        if add_docs:
            extra = synthetic.make_corpus(m=add_docs, d=d, avg_tokens=12,
                                          max_tokens=16, seed=seed + 7)
            tail = poisson_trace(rate, min(duration, 2.0), seed=seed + 8)
            add_fut = srv.add(extra.doc_tokens, extra.doc_mask)
            _, report2 = replay(srv, queries, tail)
            new_m = add_fut.result(timeout=300)
            # post-add visibility check under the exact latent scan (full
            # candidate coverage), so a query carrying a new doc's exact
            # tokens MUST retrieve it top-1 — ANN recall on out-of-
            # distribution adds is a quality question, not a correctness one
            from repro.retriever import SearchParams

            exact = SearchParams(use_ann=False, k_prime=new_m)
            target = extra.doc_tokens[0][extra.doc_mask[0]]
            _, ids = srv.search(np.asarray(target), params=exact, timeout=300)
            # clone AFTER the add so the reference sees the grown snapshot,
            # again keeping its raw-shape compile out of the server cache
            _, want = retriever.clone().search(
                target[None], np.ones((1, len(target)), bool), exact)
            add_parity = (bool(np.array_equal(ids, np.asarray(want)[0]))
                          and new_m == m + add_docs
                          and int(ids[0]) == m)
            rows.append({
                "op": "online_serving_add",
                "shape": f"m={m}+{add_docs},backend={backend},rate={rate:g}",
                **{k: report2[k] for k in ("p50_ms", "p95_ms", "p99_ms",
                                           "qps", "mean_occupancy",
                                           "n_requests")},
                "trace_count": srv.trace_count(),
                # two param sets post-add: the replay's defaults + the
                # exact-scan visibility probe
                "compile_bound": ladder.compile_bound(2),
                "parity": add_parity,
            })
            common.emit("serving_online_add_p99", rows[-1]["p99_ms"] * 1e3,
                        f"parity={add_parity}")

        mut_rows = []
        if churn_steps:
            mut_rows = _mutation_phase(
                srv, retriever, ladder, m=m, d=d, backend=backend, seed=seed,
                queries=queries, churn_steps=churn_steps)
    if churn_steps:
        mut_rows += _residual_churn_phase(
            retriever.snapshot(), m=m, d=d, backend=backend, seed=seed,
            churn_steps=churn_steps)

    life_rows = []
    if lifecycle:
        life_rows = _lifecycle_phase(
            m=m, d=d, rate=rate, duration=duration, backend=backend,
            epochs=epochs, seed=seed, max_batch=max_batch,
            max_wait_us=max_wait_us)

    out = {
        "meta": common.bench_meta(
            seed=seed, m=m, d=d, rate_qps=rate, duration_s=duration,
            ladder=list(LADDER), max_batch=ladder.max_batch,
            max_wait_us=max_wait_us, first_stage=backend,
            note="open-loop Poisson replay of ragged single queries "
                 "through repro.serving.RetrieverServer; percentile "
                 "rows are the online latency contract future PRs "
                 "are compared against"),
        "rows": rows,
        "mutation": {
            "meta": common.bench_meta(
                seed=seed, m=m, d=d, churn_steps=churn_steps,
                first_stage=backend,
                note="paged-corpus mutation trail: add/delete/update churn "
                     "under the online server (zero lost requests, monotone "
                     "snapshot versions, zero warm-pool traces, tombstones "
                     "never surface) + the add-amortization contract (paged "
                     "bytes-per-added-doc is O(doc); the flat layout's was "
                     "O(corpus)) + the compressed-tier churn contract "
                     "(residual-codec store: zero warm-pool traces, ids "
                     "bit-identical to a from-scratch compressed rebuild "
                     "over the survivors)"),
            "rows": mut_rows,
        },
        "lifecycle": {
            "meta": common.bench_meta(
                seed=seed, m=m, d=d, rate_qps=rate, first_stage=backend,
                note="learned-index lifecycle trail: Poisson replay with a "
                     "mid-stream topic-burst drift, drift detection, "
                     "background refresh, and zero-downtime warm swap under "
                     "live traffic — gated on zero lost requests, the full "
                     "typed event chain, post-swap exact-scan recall within "
                     "2% of a from-scratch rebuild on the same final "
                     "corpus, and >=60% of drift-lost ANN recall won back "
                     "at the serving operating point"),
            "rows": life_rows,
        },
    }
    if emit_json:
        _extend_bench_serving(out)

    bad = [r["op"] for r in rows + mut_rows + life_rows if not r["parity"]]
    if bad:
        raise SystemExit(f"online serving parity regression in: {bad}")
    for r in rows:
        if not math.isfinite(r["p99_ms"]):
            raise SystemExit(f"non-finite p99 in {r['op']}: {r['p99_ms']}")
        if r["trace_count"] > r["compile_bound"]:
            raise SystemExit(
                f"{r['op']}: trace_count {r['trace_count']} exceeded the "
                f"bucket-ladder compile bound {r['compile_bound']}")
    for r in mut_rows:
        if r["op"] == "mutation_churn":
            if r["n_lost"]:
                raise SystemExit(f"mutation churn lost {r['n_lost']} requests")
            if r["trace_delta"]:
                raise SystemExit(
                    f"warm-pool mutation churn issued {r['trace_delta']} new "
                    "traces (streaming-add bugfix contract: must be 0)")
        if r["op"] == "mutation_churn_residual":
            if r["trace_delta"]:
                raise SystemExit(
                    f"residual-tier churn issued {r['trace_delta']} new "
                    "traces on a warm pool (codec leaves ride jit as "
                    "arguments: must be 0)")
            if not r["rebuild_identical"]:
                raise SystemExit(
                    "residual-tier churn diverged from the from-scratch "
                    "compressed rebuild over the survivors")
        if r["op"] == "add_amortization" and not r["o_doc"]:
            raise SystemExit(
                f"paged add moved {r['paged_bytes_per_doc']:.0f} B/doc "
                f"(budget {r['doc_budget_bytes']} B/doc, flat baseline "
                f"{r['flat_bytes_per_doc']:.0f} B/doc) — not O(doc)")
    for r in life_rows:
        if r["n_lost"]:
            raise SystemExit(
                f"lifecycle swap lost {r['n_lost']} in-flight requests")
        if not (r["drift_detected"] and r["refresh_completed"]
                and r["swap_version"] is not None):
            raise SystemExit(
                "lifecycle chain incomplete: drift_detected="
                f"{r['drift_detected']} refresh_completed="
                f"{r['refresh_completed']} swap_version={r['swap_version']}")
        if r["recall_swapped"] < r["recall_rebuild"] - 0.02:
            raise SystemExit(
                f"lifecycle recall-recovery gate: post-swap recall "
                f"{r['recall_swapped']:.3f} more than 2% below the "
                f"from-scratch rebuild's {r['recall_rebuild']:.3f}")
        if r["ann_recall_recovered"] < 0.6:
            raise SystemExit(
                f"lifecycle ANN recovery gate: swap won back only "
                f"{r['ann_recall_recovered']:.0%} of the drift-lost recall "
                f"(stale {r['ann_recall_stale']:.3f} -> swapped "
                f"{r['ann_recall_swapped']:.3f}, rebuild "
                f"{r['ann_recall_rebuild']:.3f})")
    return out


def _mutation_phase(srv, retriever, ladder, *, m, d, backend, seed, queries,
                    churn_steps):
    """Add/delete/update churn through the live server -> ``mutation`` rows.

    One warm-up round first absorbs any one-time power-of-two capacity
    growth (page pool, slot table, IVF cluster caps); the measured loop
    then runs against a warm pool, where the paged-store contract is exact:
    zero new jit traces, every search resolves, every mutation bumps the
    snapshot version by exactly one, and tombstoned docs never surface in
    a post-delete search."""
    from repro.core.pages import dense_add_bytes
    from repro.data import synthetic
    from repro.retriever import SearchParams

    t_mut = time.perf_counter()
    n_add = 4
    # exact-scan params: the compiled exact path takes ONLY (ψ, stats, paged
    # store) as arguments, so its zero-new-traces contract depends on the
    # page pool alone — an IVF cluster-cap bucket growth (a different,
    # backend-owned capacity) can't blur the gate this bench enforces
    churn_params = SearchParams(use_ann=False, k=10,
                                k_prime=min(64, retriever.m))

    def batch(s):
        c = synthetic.make_corpus(m=n_add, d=d, avg_tokens=12, max_tokens=16,
                                  seed=s)
        return c.doc_tokens, c.doc_mask

    # warm-up: one full add/update/delete round (absorbs any one-time pow2
    # pool/slot growth), plus one search per Tq rung the loop will hit (the
    # ladder's per-rung first-trace cost is not what this gate measures)
    toks, mask = batch(seed + 11)
    f = srv.add(toks, mask)
    f.result(timeout=300)
    warm = np.asarray(f.added_ids)
    upd = srv.update(warm[:2], toks[:2], mask[:2]).result(timeout=300)
    srv.delete(np.concatenate([warm[2:], np.asarray(upd)])).result(timeout=300)
    churn_qs = [queries[i % len(queries)] for i in range(3 * churn_steps)]
    for bucket in {ladder.tq_bucket(len(q)) for q in churn_qs}:
        q = next(q for q in churn_qs if ladder.tq_bucket(len(q)) == bucket)
        srv.search(q, params=churn_params, timeout=300)

    v0 = retriever.version
    traces0 = srv.trace_count()
    searches, mut_futs, add_futs = [], [], []
    deleted: list[int] = []
    live = np.empty((0,), np.int64)
    for step in range(churn_steps):
        toks, mask = batch(seed + 20 + step)
        fa = srv.add(toks, mask)
        add_futs.append(fa)
        mut_futs.append(fa)
        for i in range(3):
            q = queries[(step * 3 + i) % len(queries)]
            searches.append(srv.submit(q, np.ones(len(q), bool),
                                       churn_params))
        fa.result(timeout=300)
        ids = np.asarray(fa.added_ids)
        # delete two of this step's docs, update one of the previous step's
        fd = srv.delete(ids[:2])
        mut_futs.append(fd)
        deleted.extend(ids[:2].tolist())
        if live.size:
            fu = srv.update(live[-1:], toks[:1], mask[:1])
            mut_futs.append(fu)
            deleted.append(int(live[-1]))
            live = live[:-1]
        live = np.concatenate([live, ids[2:]])
    for f in mut_futs:
        f.result(timeout=300)
    n_lost = 0
    for f in searches:
        try:
            f.result(timeout=300)
        except Exception:  # noqa: BLE001 — a lost/failed request is the gate
            n_lost += 1
    versions = [f.snapshot_version for f in mut_futs]
    monotone = (versions == sorted(versions)
                and len(set(versions)) == len(versions)
                and versions[-1] == v0 + len(mut_futs))
    # the streaming-add bugfix contract, asserted directly: re-issue the
    # SAME (params, shape) searches the warm-up compiled — after the churn
    # loop's mutations they must hit the live compiled fns with ZERO new
    # traces.  (The loop itself may legitimately compile new power-of-two
    # BATCH buckets as micro-batches coalesce — that ladder cost is bounded
    # by compile_bound, not by this gate.)
    churn_trace_delta = srv.trace_count() - traces0
    t_pre = srv.trace_count()
    for bucket in {ladder.tq_bucket(len(q)) for q in churn_qs}:
        q = next(q for q in churn_qs if ladder.tq_bucket(len(q)) == bucket)
        srv.search(q, params=churn_params, timeout=300)
    trace_delta = srv.trace_count() - t_pre

    # tombstones never surface: an exact-scan search over the full slot
    # capacity after the churn must not return any deleted id
    from repro.retriever import SearchParams

    exact = SearchParams(use_ann=False, k=10, k_prime=retriever.m)
    q = queries[0]
    _, ids_post = srv.search(q, params=exact, timeout=300)
    ghost = sorted(set(np.asarray(ids_post).ravel().tolist())
                   & set(deleted))

    # add amortization: logical bytes the paged store moved per added doc
    # (steady state, warm pool) vs what ONE flat-layout concatenate add
    # used to write at this corpus size
    st = retriever.index.store
    paged_per_doc = (sum(f.mutation_bytes for f in add_futs)
                     / (n_add * len(add_futs)))
    flat_per_doc = dense_add_bytes(retriever.m, st.td_max, st.d,
                                   st.d_prime) / n_add
    doc_budget = (st.td_max * st.d * 4 + st.pages_per_doc * 4
                  + st.d_prime * 4 + 8)
    o_doc = paged_per_doc <= 8 * doc_budget and paged_per_doc < 0.25 * flat_per_doc
    wall = time.perf_counter() - t_mut

    rows = [
        {
            "op": "mutation_churn",
            "shape": f"m={m},backend={backend},steps={churn_steps}",
            "n_mutations": len(mut_futs) + 3,     # + the warm-up round
            "n_requests": len(searches),
            "n_lost": n_lost,
            "versions_monotone": monotone,
            "final_version": versions[-1] if versions else None,
            "trace_delta": trace_delta,
            "churn_trace_delta": churn_trace_delta,
            "trace_count": srv.trace_count(),
            "n_alive": retriever.n_alive,
            "m_slots": retriever.m,
            "wall_s": wall,
            "parity": monotone and not ghost,
        },
        {
            "op": "add_amortization",
            "shape": f"m={m},backend={backend},n_add={n_add}",
            "paged_bytes_per_doc": paged_per_doc,
            "flat_bytes_per_doc": flat_per_doc,
            "ratio": paged_per_doc / flat_per_doc,
            "doc_budget_bytes": doc_budget,
            "n_adds": len(add_futs),
            "o_doc": o_doc,
            "parity": o_doc,
        },
    ]
    common.emit("serving_mutation_churn", wall * 1e6,
                f"lost={n_lost},trace_delta={trace_delta},"
                f"bytes_per_doc={paged_per_doc:.0f}/{flat_per_doc:.0f}")
    return rows


def _residual_churn_phase(snap, *, m, d, backend, seed, churn_steps):
    """Add/delete/update churn on the COMPRESSED (residual-codec) tier.

    Re-encodes the served snapshot's live corpus into a residual-4bit store
    with a constant-space pooling budget, then runs the same facade-level
    churn loop the fp32 phase ran through the server, gating on the
    compressed-store mutation contract:

    * zero new jit traces once the pool is warm and adds stay in capacity
      (the codec leaves ride jit as arguments, so mutating the compressed
      pools must not retrace);
    * every mutation bumps the snapshot version by exactly one;
    * post-churn search ids are BIT-IDENTICAL to a from-scratch compressed
      rebuild over the survivors' (pooled) tokens with the same codec —
      i.e. the in-place page mutations and the one-shot ``from_dense``
      encode are the same function of the surviving corpus."""
    import jax
    import jax.numpy as jnp

    from repro.anns.quantization import train_residual_codec
    from repro.core import pages
    from repro.data import synthetic
    from repro.retriever import LemurRetriever, SearchParams

    t0 = time.perf_counter()
    n_add, budget, bits = 4, 8, 4
    # the twin corpus: the snapshot's live docs (renumbered 0..n-1 — this
    # phase is self-contained; ann state rides along unused under exact scan)
    alive0 = np.flatnonzero(np.asarray(snap.store.alive)[:snap.m])
    toks0, mask0 = pages.gather_docs(snap.store, alive0)
    toks0, mask0 = np.asarray(toks0), np.asarray(mask0)
    W0 = np.asarray(snap.store.W)[alive0]
    ptoks, pmask = pages.pool_tokens(toks0, mask0, budget)
    codec = train_residual_codec(
        jax.random.PRNGKey(seed + 60),
        jnp.asarray(ptoks[pmask]), bits=bits, ncent=64, iters=4)
    rcfg = snap.cfg.residual.replace(enabled=True, bits=bits, ncent=64,
                                     token_budget=budget, kmeans_iters=4)
    store, _ = pages.from_dense(W0, ptoks, pmask, codec=codec)
    r = LemurRetriever(snap._replace(cfg=snap.cfg.replace(residual=rcfg),
                                     store=store))
    # raw[slot] = the POOLED tokens that slot was encoded from — the
    # rebuild-parity oracle re-encodes exactly these with the same codec
    raw = {int(i): (ptoks[i], pmask[i]) for i in range(len(alive0))}

    def batch(s):
        c = synthetic.make_corpus(m=n_add, d=d, avg_tokens=12, max_tokens=16,
                                  seed=s)
        return np.asarray(c.doc_tokens), np.asarray(c.doc_mask)

    def record(ids, toks_b, mask_b):
        pt, pm = pages.pool_tokens(toks_b, mask_b, budget)
        for j, i in enumerate(np.asarray(ids).tolist()):
            raw[int(i)] = (pt[j], pm[j])

    rng = np.random.default_rng(seed + 61)
    q = rng.standard_normal((4, 8, d)).astype(np.float32)
    qm = np.ones((4, 8), bool)
    params = SearchParams(use_ann=False, k=10, k_prime=min(64, r.m))

    # warm-up: one full round absorbs any one-time pow2 pool/slot growth,
    # one search compiles the (params, shape) the loop re-issues
    toks_b, mask_b = batch(seed + 62)
    r.add(toks_b, mask_b)
    record(r.last_added_ids, toks_b, mask_b)
    warm = np.asarray(r.last_added_ids)
    upd = r.update(warm[:1], toks_b[:1], mask_b[:1])
    raw.pop(int(warm[0]))
    record(upd, toks_b[:1], mask_b[:1])
    for i in np.concatenate([warm[1:], np.asarray(upd)]).tolist():
        raw.pop(int(i))
    r.delete(np.concatenate([warm[1:], np.asarray(upd)]))
    r.search(q, qm, params)

    v0, t_warm = r.version, r.trace_count()
    versions, live = [], []
    for step in range(churn_steps):
        toks_b, mask_b = batch(seed + 70 + step)
        r.add(toks_b, mask_b)
        versions.append(r.version)
        ids = np.asarray(r.last_added_ids)
        record(ids, toks_b, mask_b)
        r.search(q, qm, params)
        for i in ids[:2].tolist():
            raw.pop(int(i))
        r.delete(ids[:2])
        versions.append(r.version)
        if live:
            raw.pop(live[-1])
            upd = r.update([live.pop()], toks_b[:1], mask_b[:1])
            versions.append(r.version)
            record(upd, toks_b[:1], mask_b[:1])
            live.extend(np.asarray(upd).tolist())
        live.extend(ids[2:].tolist())
    trace_delta = r.trace_count() - t_warm
    monotone = versions == list(range(v0 + 1, v0 + len(versions) + 1))

    # from-scratch compressed rebuild over the survivors: same pooled
    # tokens, same codec, one-shot from_dense — ids must map bit-identically
    st = r.index.store
    surv = sorted(raw)
    assert len(surv) == r.n_alive
    rt = np.zeros((len(surv), budget, d), np.float32)
    rm = np.zeros((len(surv), budget), bool)
    for j, i in enumerate(surv):
        t, mk = raw[i]
        rt[j, : mk.sum()] = t[mk]
        rm[j, : mk.sum()] = True
    store2, _ = pages.from_dense(np.asarray(st.W)[surv], rt, rm,
                                 codec=st.codec)
    r2 = LemurRetriever(r.index._replace(store=store2))
    _, ids_a = r.search(q, qm, params)
    _, ids_b = r2.search(q, qm, params)
    rebuild_identical = bool(np.array_equal(
        np.asarray(ids_a), np.asarray(surv, np.int64)[np.asarray(ids_b)]))
    wall = time.perf_counter() - t0

    row = {
        "op": "mutation_churn_residual",
        "shape": (f"m={len(alive0)},backend={backend},steps={churn_steps},"
                  f"bits={bits},budget={budget}"),
        "n_mutations": len(versions),
        "versions_monotone": monotone,
        "final_version": versions[-1] if versions else None,
        "trace_delta": trace_delta,
        "trace_count": r.trace_count(),
        "n_alive": r.n_alive,
        "m_slots": r.m,
        "bytes_per_doc": pages.token_bytes(st) / max(r.n_alive, 1),
        "rebuild_identical": rebuild_identical,
        "wall_s": wall,
        "parity": monotone and trace_delta == 0 and rebuild_identical,
    }
    common.emit("serving_mutation_churn_residual", wall * 1e6,
                f"trace_delta={trace_delta},rebuild_identical="
                f"{rebuild_identical},B/doc={row['bytes_per_doc']:.0f}")
    return [row]


def _lifecycle_phase(*, m, d, rate, duration, backend, epochs, seed,
                     max_batch, max_wait_us):
    """Drift -> background refresh -> warm swap under live Poisson traffic.

    Three replay slices against a dedicated server: a steady slice on the
    as-built corpus (the monitor must stay QUIET — no false triggers on
    in-distribution traffic), a drift slice with a strongly-expressed topic
    burst plus deletes fanned through the mutation barrier mid-replay, and
    a post-drift slice replayed WHILE the manager detects the drift, runs
    ``build_refresh`` on a side thread, and installs the result through the
    server's FIFO swap barrier.  Gates (SystemExit in ``run``): zero lost
    requests across all slices; the full typed event chain
    (DriftDetected -> RefreshCompleted -> SwapCompleted); post-swap recall
    of the refit learned map (exact latent scan, tight candidate budget)
    within 2% of a from-scratch ``LemurRetriever.build`` on the same final
    live corpus; and the swap recovering >= 60% of the drift-lost recall at
    the ANN serving operating point.  The ANN side is gated on the recovery
    FRACTION, not the 2% margin: two independently k-means-initialised IVF
    indexes differ by ~5% recall from init noise alone at this scale, so a
    2% absolute comparison there would gate on the init lottery — the
    exact-scan measurement is deterministic and isolates what the refresh
    actually refits."""
    import threading

    import jax
    import jax.numpy as jnp  # noqa: F401 — jax must be initialized first

    from repro.core import LemurConfig
    from repro.core import maxsim as mx
    from repro.core.pages import gather_docs
    from repro.data import synthetic
    from repro.lifecycle import (
        DriftDetected,
        DriftMonitor,
        LifecycleManager,
        RefreshCompleted,
        SwapCompleted,
    )
    from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams
    from repro.serving import (
        BucketLadder,
        RetrieverServer,
        poisson_trace,
        ragged_queries,
        replay,
        warm_buckets,
    )

    t0 = time.perf_counter()
    m_life = min(m, 600)
    n_burst, n_delete = 192, 120
    corpus = synthetic.make_corpus(m=m_life, d=d, avg_tokens=12, max_tokens=16,
                                   seed=seed + 40)
    cfg = LemurConfig(d=d, d_prime=64, m_pretrain=min(256, m_life),
                      n_train=4096, n_ols=1024, epochs=epochs, k=10,
                      k_prime=min(128, m_life), anns=backend,
                      ivf=IVFBackendConfig(nprobe=16))
    r = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(seed + 40))
    ladder = BucketLadder(LADDER, max_batch=max_batch)
    queries = ragged_queries(128, d, tq_range=(2, 24), seed=seed + 41)
    slice_s = max(min(duration / 3.0, 3.0), 1.0)
    # the drift workload: a topic burst far outside the build distribution
    burst = synthetic.make_corpus(m=n_burst, d=d, avg_tokens=12, max_tokens=16,
                                  n_centers=6, topic_strength=4.0, seed=777)
    with RetrieverServer(r, ladder=ladder, max_wait_us=max_wait_us) as srv:
        warm_buckets(r, ladder, d)
        mon = DriftMonitor(r, seed=seed)
        mgr = LifecycleManager(srv, monitor=mon, seed=seed + 1,
                               cooldown_s=0.0, min_reservoir=64)
        mgr.start(auto=False)
        try:
            # steady slice: empty reservoir / in-distribution -> no trigger
            _, rep_pre = replay(srv, queries,
                                poisson_trace(rate, slice_s, seed=seed + 42))
            quiet = not mgr.poll_once()
            # drift slice: burst + deletes land through the mutation barrier
            # while the replay keeps submitting
            fa = srv.add(burst.doc_tokens, burst.doc_mask)
            fd = srv.delete(np.arange(n_delete))
            _, rep_mid = replay(srv, queries,
                                poisson_trace(rate, slice_s, seed=seed + 43))
            fa.result(timeout=300)
            fd.result(timeout=300)
            v0 = r.version
            stale = r.clone()       # the drifted pre-swap index, for the
                                    # recall-recovery measurement below
            # post-drift slice replays WHILE the manager detects, rebuilds,
            # and warm-swaps — the in-flight searches must all resolve
            swap_ok: dict = {}
            th = threading.Thread(
                target=lambda: swap_ok.__setitem__("ok", mgr.poll_once()))
            th.start()
            _, rep_post = replay(srv, queries,
                                 poisson_trace(rate, slice_s, seed=seed + 44))
            th.join(timeout=600)
            detected = bool(mgr.events(DriftDetected))
            refreshed = bool(mgr.events(RefreshCompleted))
            swaps = mgr.events(SwapCompleted)
        finally:
            mgr.stop()

    # recall-recovery gates against exact-MaxSim truth on the final live
    # corpus, queries drawn from the drifted (burst) distribution
    alive = np.flatnonzero(np.asarray(r.index.store.alive)[:r.m])
    dt, dm = gather_docs(r.index.store, alive)
    dt, dm = np.asarray(dt), np.asarray(dm)
    q = synthetic.queries_held_out(burst, 32, q_tokens=8, topic_strength=4.0,
                                   seed=seed + 45)
    qm = np.ones(q.shape[:2], bool)
    t_ids = np.asarray(mx.true_topk(q, qm, dt, dm, 10)[1])
    live = synthetic.MultiVectorCorpus(dt, dm,
                                       np.zeros((len(alive), 1), np.int32),
                                       np.zeros((1, d), np.float32))
    fresh = LemurRetriever.build(live, cfg, key=jax.random.PRNGKey(seed + 40))

    def _recall(rt, params, fresh_ids=False):
        # ``fresh`` numbers docs 0..n_alive-1; the served index uses slots
        truth = t_ids if fresh_ids else alive[t_ids]
        _, ids = rt.search(q, qm, params)
        return float(np.mean(np.asarray(mx.recall_at(np.asarray(ids),
                                                     truth))))

    # deterministic gate: the refit latent map, exact first stage at a
    # tight candidate budget (no clustering-init noise on either side)
    exact = SearchParams(k=10, k_prime=min(48, int(r.m)), use_ann=False)
    swapped_recall = _recall(r, exact)
    rebuild_recall = _recall(fresh, exact, fresh_ids=True)
    # serving-operating-point recovery: how much of the drift-lost ANN
    # recall did the recluster win back
    ann = SearchParams(k=10, k_prime=min(128, int(r.m)))
    ann_stale = _recall(stale, ann)
    ann_swapped = _recall(r, ann)
    ann_rebuild = _recall(fresh, ann, fresh_ids=True)
    recovered = ((ann_swapped - ann_stale)
                 / max(ann_rebuild - ann_stale, 1e-9)
                 if ann_rebuild > ann_stale else 1.0)

    n_lost = rep_pre["n_lost"] + rep_mid["n_lost"] + rep_post["n_lost"]
    wall = time.perf_counter() - t0
    row = {
        "op": "lifecycle_swap",
        "shape": (f"m={m_life}+{n_burst}-{n_delete},backend={backend},"
                  f"rate={rate:g},burst_strength=4.0"),
        "p99_ms_pre": rep_pre["p99_ms"],
        "p99_ms_during_drift": rep_mid["p99_ms"],
        "p99_ms_during_swap": rep_post["p99_ms"],
        "n_requests": (rep_pre["n_requests"] + rep_mid["n_requests"]
                       + rep_post["n_requests"]),
        "n_lost": n_lost,
        "quiet_before_drift": quiet,
        "drift_detected": detected,
        "refresh_completed": refreshed,
        "refresh_wall_s": (mgr.last_refresh_result.wall_s
                           if mgr.last_refresh_result else None),
        "swap_version": swaps[-1].version if swaps else None,
        "version_delta": int(r.version) - v0,
        "caught_up": swaps[-1].caught_up if swaps else None,
        "recall_swapped": swapped_recall,
        "recall_rebuild": rebuild_recall,
        "recall_gap": rebuild_recall - swapped_recall,
        "ann_recall_stale": ann_stale,
        "ann_recall_swapped": ann_swapped,
        "ann_recall_rebuild": ann_rebuild,
        "ann_recall_recovered": recovered,
        "wall_s": wall,
        "parity": (quiet and detected and refreshed and bool(swaps)
                   and bool(swap_ok.get("ok")) and n_lost == 0
                   and swapped_recall >= rebuild_recall - 0.02
                   and recovered >= 0.6),
    }
    common.emit("serving_lifecycle_swap", wall * 1e6,
                f"recall={swapped_recall:.3f}/{rebuild_recall:.3f},"
                f"ann_recovered={recovered:.2f},lost={n_lost},"
                f"caught_up={row['caught_up']}")
    return [row]


def _extend_bench_serving(online: dict) -> None:
    """Merge the online section into the repo-root BENCH_serving.json with
    merge-preserve semantics (the BENCH_kernels.json fix): the offline
    fused-vs-legacy rows written by table2_qps are untouched, ``online`` rows
    this run did not re-measure survive verbatim, and the section meta is
    restamped with jax/device/seed provenance."""
    doc = common.load_bench_root("serving")
    common.merge_section(doc, "online", online["meta"], online["rows"])
    mut = online.get("mutation", {})
    if mut.get("rows"):
        common.merge_section(doc, "mutation", mut["meta"], mut["rows"])
    life = online.get("lifecycle", {})
    if life.get("rows"):
        common.merge_section(doc, "lifecycle", life["meta"], life["rows"])
    common.save_bench_root("serving", doc)


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--m", type=int, default=2000)
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--rate", type=float, default=100.0,
                   help="offered load, queries/second (Poisson)")
    p.add_argument("--duration", type=float, default=10.0)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--max-wait-us", type=int, default=2000)
    p.add_argument("--backend", default="ivf")
    p.add_argument("--epochs", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--add-docs", type=int, default=32,
                   help="docs streamed in mid-replay (0 disables)")
    p.add_argument("--churn-steps", type=int, default=4,
                   help="add/delete/update churn rounds for the mutation "
                        "smoke (0 disables)")
    p.add_argument("--lifecycle", action="store_true",
                   help="run the drift -> refresh -> warm-swap phase and "
                        "gate post-swap recall against a from-scratch "
                        "rebuild")
    p.add_argument("--no-emit-json", action="store_true",
                   help="skip extending the repo-root BENCH_serving.json")
    a = p.parse_args()
    out = run(a.m, d=a.d, rate=a.rate, duration=a.duration,
              max_batch=a.max_batch, max_wait_us=a.max_wait_us,
              backend=a.backend, epochs=a.epochs, seed=a.seed,
              add_docs=a.add_docs, churn_steps=a.churn_steps,
              lifecycle=a.lifecycle, emit_json=not a.no_emit_json)
    print(json.dumps(out["rows"] + out["mutation"]["rows"]
                     + out["lifecycle"]["rows"], indent=1))
