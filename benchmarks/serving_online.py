"""Online-serving benchmark: Poisson arrival replay through RetrieverServer.

Replays a seeded Poisson trace of ragged single queries against the online
runtime (``repro.serving``) in front of a LEMUR retriever, then EXTENDS the
repo-root ``BENCH_serving.json`` perf trail with latency-percentile rows —
the offline fused-vs-legacy rows written by ``table2_qps.serving_perf`` are
preserved; this bench owns the ``"online"`` section:

    {"meta": {...}, "rows": [...],            # offline (table2_qps)
     "online": {"meta": {...}, "rows": [      # this bench
        {"op": "online_serving", "p50_ms": ..., "p95_ms": ..., "p99_ms": ...,
         "qps": ..., "offered_qps": ..., "mean_occupancy": ...,
         "trace_count": ..., "compile_bound": ..., "parity": true}, ...]}}

Every run asserts the serving contract (SystemExit on violation, so the CI
bench-smoke job fails):

* **parity** — a sample of replayed requests is re-answered by a direct
  ``retriever.search`` of the raw ragged query; top-k ids must be
  bit-identical.
* **p99 finite** — percentiles must be real numbers (a deadlocked or
  request-dropping micro-batcher would poison them).
* **compile bound** — ``trace_count()`` never exceeds the bucket ladder's
  bound, no matter the trace's shape churn.

  PYTHONPATH=src python -m benchmarks.serving_online                # default
  PYTHONPATH=src python -m benchmarks.serving_online --m 600 --duration 10 \\
      --rate 50 --epochs 4                                          # CI smoke
"""
from __future__ import annotations

import argparse
import json
import math

import numpy as np

from benchmarks import common

LADDER = (8, 16, 32)


def run(m: int = 2000, *, d: int = 32, rate: float = 100.0,
        duration: float = 10.0, max_batch: int = 8, max_wait_us: int = 2000,
        backend: str = "ivf", epochs: int = 10, seed: int = 0,
        add_docs: int = 32, parity_sample: int = 16,
        emit_json: bool = True) -> dict:
    import jax

    from repro.core import LemurConfig
    from repro.data import synthetic
    from repro.retriever import IVFBackendConfig, LemurRetriever
    from repro.serving import (
        BucketLadder,
        RetrieverServer,
        poisson_trace,
        ragged_queries,
        replay,
        warm_buckets,
    )

    corpus = synthetic.make_corpus(m=m, d=d, avg_tokens=12, max_tokens=16,
                                   seed=seed)
    cfg = LemurConfig(d=d, d_prime=64, m_pretrain=min(256, m),
                      n_train=4096, n_ols=1024, epochs=epochs, k=10,
                      k_prime=min(128, m), anns=backend,
                      ivf=IVFBackendConfig(nprobe=16))
    retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(seed))
    ladder = BucketLadder(LADDER, max_batch=max_batch)
    queries = ragged_queries(256, d, tq_range=(2, 24), seed=seed + 1)
    arrivals = poisson_trace(rate, duration, seed=seed + 2)

    rows = []
    with RetrieverServer(retriever, ladder=ladder,
                         max_wait_us=max_wait_us) as srv:
        warmed = warm_buckets(retriever, ladder, d)
        results, report = replay(srv, queries, arrivals)

        # parity: a request sample re-answered by direct facade search
        rng = np.random.default_rng(seed + 3)
        sample = rng.choice(len(results), min(parity_sample, len(results)),
                            replace=False)
        parity = True
        for i in sample:
            q = queries[i % len(queries)]
            _, want = retriever.search(q[None], np.ones((1, len(q)), bool))
            parity &= bool(np.array_equal(results[i][1], np.asarray(want)[0]))

        bound = ladder.compile_bound(1)
        rows.append({
            "op": "online_serving",
            "shape": (f"m={m},backend={backend},rate={rate:g},"
                      f"ladder={'/'.join(map(str, LADDER))},"
                      f"max_batch={ladder.max_batch},"
                      f"max_wait_us={max_wait_us}"),
            **{k: report[k] for k in ("p50_ms", "p95_ms", "p99_ms", "mean_ms",
                                      "qps", "offered_qps", "mean_occupancy",
                                      "n_requests", "n_batches")},
            "trace_count": report["trace_count"],
            "compile_bound": bound,
            "warmed_shapes": warmed,
            "parity": parity,
        })
        common.emit("serving_online_p99", rows[-1]["p99_ms"] * 1e3,
                    f"p50={rows[-1]['p50_ms']:.2f}ms,"
                    f"qps={rows[-1]['qps']:.0f},"
                    f"occ={rows[-1]['mean_occupancy']:.2f}")

        # add-while-serving: stream growth mid-replay, re-check parity on a
        # post-add query targeting a brand-new doc
        if add_docs:
            extra = synthetic.make_corpus(m=add_docs, d=d, avg_tokens=12,
                                          max_tokens=16, seed=seed + 7)
            tail = poisson_trace(rate, min(duration, 2.0), seed=seed + 8)
            add_fut = srv.add(extra.doc_tokens, extra.doc_mask)
            _, report2 = replay(srv, queries, tail)
            new_m = add_fut.result(timeout=300)
            # post-add visibility check under the exact latent scan (full
            # candidate coverage), so a query carrying a new doc's exact
            # tokens MUST retrieve it top-1 — ANN recall on out-of-
            # distribution adds is a quality question, not a correctness one
            from repro.retriever import SearchParams

            exact = SearchParams(use_ann=False, k_prime=new_m)
            target = extra.doc_tokens[0][extra.doc_mask[0]]
            _, ids = srv.search(np.asarray(target), params=exact, timeout=300)
            _, want = retriever.search(target[None],
                                       np.ones((1, len(target)), bool), exact)
            add_parity = (bool(np.array_equal(ids, np.asarray(want)[0]))
                          and new_m == m + add_docs
                          and int(ids[0]) == m)
            rows.append({
                "op": "online_serving_add",
                "shape": f"m={m}+{add_docs},backend={backend},rate={rate:g}",
                **{k: report2[k] for k in ("p50_ms", "p95_ms", "p99_ms",
                                           "qps", "mean_occupancy",
                                           "n_requests")},
                "trace_count": srv.trace_count(),
                # two param sets post-add: the replay's defaults + the
                # exact-scan visibility probe
                "compile_bound": ladder.compile_bound(2),
                "parity": add_parity,
            })
            common.emit("serving_online_add_p99", rows[-1]["p99_ms"] * 1e3,
                        f"parity={add_parity}")

    out = {
        "meta": common.bench_meta(
            seed=seed, m=m, d=d, rate_qps=rate, duration_s=duration,
            ladder=list(LADDER), max_batch=ladder.max_batch,
            max_wait_us=max_wait_us, first_stage=backend,
            note="open-loop Poisson replay of ragged single queries "
                 "through repro.serving.RetrieverServer; percentile "
                 "rows are the online latency contract future PRs "
                 "are compared against"),
        "rows": rows,
    }
    if emit_json:
        _extend_bench_serving(out)

    bad = [r["op"] for r in rows if not r["parity"]]
    if bad:
        raise SystemExit(f"online serving parity regression in: {bad}")
    for r in rows:
        if not math.isfinite(r["p99_ms"]):
            raise SystemExit(f"non-finite p99 in {r['op']}: {r['p99_ms']}")
        if r["trace_count"] > r["compile_bound"]:
            raise SystemExit(
                f"{r['op']}: trace_count {r['trace_count']} exceeded the "
                f"bucket-ladder compile bound {r['compile_bound']}")
    return out


def _extend_bench_serving(online: dict) -> None:
    """Merge the online section into the repo-root BENCH_serving.json with
    merge-preserve semantics (the BENCH_kernels.json fix): the offline
    fused-vs-legacy rows written by table2_qps are untouched, ``online`` rows
    this run did not re-measure survive verbatim, and the section meta is
    restamped with jax/device/seed provenance."""
    doc = common.load_bench_root("serving")
    common.merge_section(doc, "online", online["meta"], online["rows"])
    common.save_bench_root("serving", doc)


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--m", type=int, default=2000)
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--rate", type=float, default=100.0,
                   help="offered load, queries/second (Poisson)")
    p.add_argument("--duration", type=float, default=10.0)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--max-wait-us", type=int, default=2000)
    p.add_argument("--backend", default="ivf")
    p.add_argument("--epochs", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--add-docs", type=int, default=32,
                   help="docs streamed in mid-replay (0 disables)")
    p.add_argument("--no-emit-json", action="store_true",
                   help="skip extending the repo-root BENCH_serving.json")
    a = p.parse_args()
    out = run(a.m, d=a.d, rate=a.rate, duration=a.duration,
              max_batch=a.max_batch, max_wait_us=a.max_wait_us,
              backend=a.backend, epochs=a.epochs, seed=a.seed,
              add_docs=a.add_docs, emit_json=not a.no_emit_json)
    print(json.dumps(out["rows"], indent=1))
