"""Shared benchmark substrate: one synthetic corpus + trained LEMUR indexes,
cached across the per-figure benchmarks (building the d'-ablation indexes is
the expensive step)."""
from __future__ import annotations

import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LemurConfig, maxsim
from repro.data import synthetic

RESULTS = pathlib.Path("results")
RESULTS.mkdir(exist_ok=True)
# repo root, for the committed BENCH_*.json perf trajectory (machine-readable
# fused-vs-legacy serving numbers future PRs are held to)
REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

# CPU-scaled benchmark setting (statistics mirror SCIDOCS: m≈25k docs).
M, D, AVG_T, MAX_T = 12000, 48, 16, 24
N_QUERIES, Q_TOKENS, K = 96, 8, 10

_BENCH_CFG = dict(m_pretrain=1024, n_train=16384, n_ols=4096, epochs=80,
                  batch_size=512, lr=3e-3, grad_clip=0.5, k=K)


@functools.lru_cache(maxsize=1)
def corpus():
    return synthetic.make_corpus(m=M, d=D, avg_tokens=AVG_T, max_tokens=MAX_T,
                                 n_centers=96, topic_strength=1.6, seed=0)


@functools.lru_cache(maxsize=1)
def queries():
    c = corpus()
    q = jnp.asarray(synthetic.queries_from_corpus_query(c, N_QUERIES, Q_TOKENS,
                                                        encoder_noise=0.15, seed=99))
    qm = jnp.ones(q.shape[:2], bool)
    return q, qm


@functools.lru_cache(maxsize=1)
def ground_truth():
    c = corpus()
    q, qm = queries()
    docs = jnp.asarray(c.doc_tokens)
    mask = jnp.asarray(c.doc_mask)
    _, truth = maxsim.true_topk(q, qm, docs, mask, K)
    return truth


def lemur_retriever(d_prime: int, query_strategy: str = "corpus-query",
                    backend: str = "ivf"):
    """A FRESH facade over the cached build — callers may mutate (add docs)
    without corrupting the shared cache entry."""
    from repro.retriever import LemurRetriever

    return LemurRetriever(_cached_retriever(d_prime, query_strategy,
                                            backend).index)


@functools.lru_cache(maxsize=8)
def _cached_retriever(d_prime: int, query_strategy: str = "corpus-query",
                      backend: str = "ivf"):
    """Deterministic build; disk-cached (psi params + W) so repeated benchmark
    runs skip the training/OLS stage and only re-measure query latency.  The
    cached reduction is shared across backends — only the (cheap) first-stage
    state is rebuilt per ``backend`` (``LemurRetriever.with_backend``)."""
    import numpy as np

    from repro.anns.params import IVFBackendConfig
    from repro.core.index import LemurIndex
    from repro.core.model import TargetStats
    from repro.retriever import LemurRetriever

    cfg = LemurConfig(d=D, d_prime=d_prime, anns=backend,
                      ivf=IVFBackendConfig(nprobe=32, sq8=True),
                      k_prime=512, query_strategy=query_strategy, **_BENCH_CFG)
    cache = RESULTS / f"bench_index_m{M}_d{d_prime}_{query_strategy}_e{cfg.epochs}.npz"
    c = corpus()
    if cache.exists():
        z = np.load(cache)
        psi = {"dense": {"kernel": jnp.asarray(z["k"]), "bias": jnp.asarray(z["b"])},
               "ln": {"scale": jnp.asarray(z["g"]), "bias": jnp.asarray(z["beta"])}}
        idx = LemurIndex.from_dense(
            cfg, psi, TargetStats(jnp.asarray(z["mean"]), jnp.asarray(z["std"])),
            jnp.asarray(z["W"]), jnp.asarray(c.doc_tokens),
            jnp.asarray(c.doc_mask), "bruteforce", None)
        return LemurRetriever(idx).with_backend(backend, key=jax.random.PRNGKey(3),
                                                cfg=cfg)
    r = LemurRetriever.build(c, cfg, key=jax.random.PRNGKey(0))
    idx = r.index
    np.savez(cache, k=np.asarray(idx.psi["dense"]["kernel"]),
             b=np.asarray(idx.psi["dense"]["bias"]),
             g=np.asarray(idx.psi["ln"]["scale"]), beta=np.asarray(idx.psi["ln"]["bias"]),
             mean=np.asarray(idx.stats.mean), std=np.asarray(idx.stats.std),
             W=np.asarray(idx.W))
    return r


def lemur_index(d_prime: int, query_strategy: str = "corpus-query",
                backend: str = "ivf"):
    """v0 shim: the bare LemurIndex behind :func:`lemur_retriever`."""
    return lemur_retriever(d_prime, query_strategy, backend).index


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock seconds per call (jit-compiled fns; blocks on ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    """CSV line per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, obj):
    (RESULTS / f"bench_{name}.json").write_text(json.dumps(obj, indent=1))


def save_bench_root(name: str, obj):
    """Write ``BENCH_<name>.json`` at the REPO ROOT (the committed perf
    trajectory — ``results/`` holds per-run scratch, these hold the numbers
    the next PR is compared against)."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(obj, indent=1) + "\n")
    return path


def bench_meta(**extra) -> dict:
    """The per-emission provenance stamp every BENCH_*.json section carries
    (the BENCH_kernels.json schema): which jax, which device, which seed —
    so a TPU trajectory is never silently compared against a CPU rerun."""
    return {
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "jax_version": jax.__version__,
        "seed": 0,
        **extra,
    }


def merge_section(doc: dict, section: str, meta: dict, rows: list[dict],
                  key_fields=("op", "shape")) -> dict:
    """Merge freshly measured ``rows`` into ``doc[section]`` with
    merge-preserve semantics: a row's identity is ``key_fields`` (+ the
    emitting backend), rows this run did NOT re-measure are preserved
    verbatim, re-measured identities are replaced, and the section's
    ``meta`` is restamped.  Returns ``doc`` (mutated) — callers load the
    committed BENCH_*.json, merge each section they measured, and save."""
    prev = doc.get(section, {})
    # pre-merge sections stamped the platform as "backend_platform" — honor
    # it as the identity fallback so their rows dedupe against re-measures
    prev_meta = prev.get("meta", {})
    prev_backend = (prev_meta.get("backend")
                    or prev_meta.get("backend_platform"))

    def key(r, fallback):
        return tuple(r.get(f) for f in key_fields) + (
            r.get("backend", fallback),)

    prev_rows = {key(r, prev_backend): r for r in prev.get("rows", [])}
    fresh = {key(r, meta.get("backend")) for r in rows}
    doc[section] = {
        "meta": meta,
        "rows": list(rows) + [r for kk, r in prev_rows.items()
                              if kk not in fresh],
    }
    return doc


def load_bench_root(name: str) -> dict:
    """The committed ``BENCH_<name>.json`` (or ``{}`` before first emit)."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    return json.loads(path.read_text()) if path.exists() else {}


def bench_row(op: str, shape: str, legacy_s: float, fused_s: float,
              gathered_bytes: int, *, parity: bool,
              flops: float | None = None,
              launches: dict[str, int] | None = None,
              backend: str | None = None) -> dict:
    """One fused-vs-legacy row of the BENCH_*.json contract: wall-µs per
    call for both paths, effective GB/s over the logical gathered bytes
    (same byte count for both paths — the fused path streams them once,
    the legacy path materializes them in HBM first), and the speedup.

    Optional perf-trail columns (the roofline ratchet):
    ``flops`` adds ``roofline_frac`` — the fused path's measured time vs the
    analytic roofline of the op (``launch.roofline.kernel_roofline`` over
    ``flops``/``gathered_bytes``); bench-smoke gates on it regressing.
    ``launches`` records the pre-rerank kernel-launch count per path (e.g.
    ``{"legacy": 3, "fused": 1}`` for the one-launch query).  ``backend``
    stamps the row with the jax backend that produced it, so TPU rows are
    never compared against CPU rows."""
    row = {
        "op": op,
        "shape": shape,
        "legacy_us": legacy_s * 1e6,
        "fused_us": fused_s * 1e6,
        "fused_vs_legacy": legacy_s / max(fused_s, 1e-12),
        "gathered_bytes": int(gathered_bytes),
        "legacy_gbps": gathered_bytes / max(legacy_s, 1e-12) / 1e9,
        "fused_gbps": gathered_bytes / max(fused_s, 1e-12) / 1e9,
        "parity": bool(parity),
        "backend": backend if backend is not None else jax.default_backend(),
    }
    if launches is not None:
        row["launches"] = dict(launches)
    if flops is not None:
        from repro.launch.roofline import kernel_roofline

        row["roofline_frac"] = kernel_roofline(
            float(flops), float(gathered_bytes), fused_s)["roofline_frac"]
    return row
