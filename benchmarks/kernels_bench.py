"""Kernel-layer micro-benchmarks: ops-vs-ref wall time (CPU: reference path
is the measurement; the Pallas path is TPU-targeted and validated in
interpret mode by tests).  Reports the arithmetic layout costs that drive
the §Perf napkin math, plus the fused-vs-legacy gather rows that feed the
repo-root ``BENCH_kernels.json`` perf trajectory."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.anns.quantization import sq8_quant
from repro.kernels import ops, ref


def _gather_rows(rng):
    """Fused-vs-legacy rows at the raw kernel/oracle level: the probe-scan
    and rerank score stages, stripped of index build and top-k, through the
    real ``ops`` dispatch (parity asserted per row).

    ``REPRO_BENCH_INTERPRET=1`` (the CI bench-smoke job) additionally runs
    the Pallas kernels in interpret mode on a small slice and folds the
    result into each row's parity bit — a kernel-body regression fails the
    bench even on a CPU runner."""
    import os

    from repro.core import maxsim

    interpret = os.environ.get("REPRO_BENCH_INTERPRET") == "1"
    rows = []
    # IVF probe scan stage: (B, nprobe) clusters of (cap, d)
    B, nlist, cap, d, nprobe = 64, 128, 128, 128, 16
    ids = jnp.asarray(rng.integers(0, 1 << 20, (nlist, cap)), jnp.int32)
    vecs = jnp.asarray(rng.standard_normal((nlist, cap, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    probe = jnp.asarray(rng.integers(0, nlist, (B, nprobe)), jnp.int32)
    codes, scales = sq8_quant(vecs)
    for name, v, s, item in (("ivf_scan_fp32", vecs, None, 4),
                             ("ivf_scan_sq8", codes, scales, 1)):
        legacy = jax.jit(lambda qq, pp, v=v, s=s: ref.ivf_scan_ref(
            qq, pp, ids, v, s))
        fused = jax.jit(lambda qq, pp, v=v, s=s: ops.fused_ivf_scan(
            qq, pp, ids, v, s))
        lo, fo = legacy(q, probe), fused(q, probe)
        parity = bool(np.allclose(np.asarray(lo), np.asarray(fo)))
        if interpret:
            ko = ops.fused_ivf_scan(q[:4], probe[:4], ids, v, s,
                                    use_kernel=True)
            tol = 1e-6 if s is None else 2 ** -13
            parity &= bool(np.allclose(np.asarray(ko), np.asarray(lo[:4]),
                                       rtol=tol, atol=1e-3))
        gathered = B * nprobe * cap * (d * item + 4 + (4 if s is not None else 0))
        rows.append(common.bench_row(
            name, f"B={B},nprobe={nprobe},cap={cap},d={d}",
            common.timeit(legacy, q, probe), common.timeit(fused, q, probe),
            gathered, parity=parity, flops=2 * B * nprobe * cap * d,
            launches={"legacy": 1, "fused": 1}))
        common.emit(f"kernel_{name}", rows[-1]["fused_us"],
                    f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy")

    # candidate-gather rerank stage: (B, k') docs of (Td, d)
    B, m, Tq, Td, d, kp = 32, 8192, 8, 16, 128, 128
    qt = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.ones((B, Tq), bool)
    docs = jnp.asarray(rng.standard_normal((m, Td, d)), jnp.float32)
    dm = jnp.asarray(rng.random((m, Td)) > 0.2).at[:, 0].set(True)
    cand = jnp.asarray(rng.integers(0, m, (B, kp)), jnp.int32)
    legacy = jax.jit(lambda a, b, c: maxsim.rerank(a, b, c, docs, dm, 10))
    fused = jax.jit(lambda a, b, c: ops.fused_rerank(a, b, c, docs, dm, 10))
    _, li = legacy(qt, qm, cand)
    _, fi = fused(qt, qm, cand)
    parity = bool(np.array_equal(np.asarray(li), np.asarray(fi)))
    if interpret:
        _, ki = ops.fused_rerank(qt[:2], qm[:2], cand[:2], docs, dm, 10,
                                 use_kernel=True)
        parity &= bool(np.array_equal(np.asarray(ki), np.asarray(li[:2])))
    rows.append(common.bench_row(
        "rerank", f"B={B},k_prime={kp},Tq={Tq},Td={Td},d={d}",
        common.timeit(legacy, qt, qm, cand), common.timeit(fused, qt, qm, cand),
        B * kp * Td * (d * 4 + 4), parity=parity,
        flops=2 * B * kp * Tq * Td * d, launches={"legacy": 1, "fused": 1}))
    common.emit("kernel_rerank_fused", rows[-1]["fused_us"],
                f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy")
    rows.extend(_one_launch_rows(rng, interpret))
    return rows


def _one_launch_rows(rng, interpret: bool):
    """One-launch query rows: the legacy 3-launch first stage (ψ-pool →
    probe scan → top-k', ``pool_queries`` + ``search_ivf``) vs the fused
    ``search_ivf_one_launch`` path, fp32 and SQ8.  Parity = bit-identical
    candidate ids; under ``REPRO_BENCH_INTERPRET=1`` the actual Pallas
    kernel additionally runs (interpret mode) on a small slice and must
    match the legacy ids too (SQ8 scores to the hi/lo-bf16 tolerance)."""
    import jax.numpy as jnp  # noqa: F811 (kept local for symmetry)

    from repro.anns.ivf import IVFIndex, search_ivf, search_ivf_one_launch
    from repro.core.model import pool_queries

    rows = []
    B, Tq, d, dp = 64, 8, 64, 128
    nlist, cap, nprobe, kp = 64, 64, 8, 128
    psi = {"dense": {"kernel": jnp.asarray(rng.standard_normal((d, dp)) * 0.1,
                                           jnp.float32),
                     "bias": jnp.asarray(rng.standard_normal(dp) * 0.01,
                                         jnp.float32)},
           "ln": {"scale": jnp.asarray(1 + 0.1 * rng.standard_normal(dp),
                                       jnp.float32),
                  "bias": jnp.asarray(0.1 * rng.standard_normal(dp),
                                      jnp.float32)}}
    qt = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.asarray(rng.random((B, Tq)) > 0.2).at[:, 0].set(True)
    cents = jnp.asarray(rng.standard_normal((nlist, dp)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 1 << 20, (nlist, cap)), jnp.int32)
    ids = ids.at[:, -4:].set(-1)                       # pad slots in play
    vecs = jnp.asarray(rng.standard_normal((nlist, cap, dp)), jnp.float32)
    codes, scales = sq8_quant(vecs)
    counts = jnp.full((nlist,), cap - 4, jnp.int32)
    for name, v, s, item in (("one_launch_query_fp32", vecs, None, 4),
                             ("one_launch_query_sq8", codes, scales, 1)):
        idx = IVFIndex(cents, ids, v, s, counts)
        legacy = jax.jit(lambda a, b, idx=idx: search_ivf(
            idx, pool_queries(psi, a, b), nprobe, kp))
        fused = jax.jit(lambda a, b, idx=idx: search_ivf_one_launch(
            idx, psi, a, b, nprobe, kp))
        (ls, li), (fs, fi) = legacy(qt, qm), fused(qt, qm)
        parity = bool(np.array_equal(np.asarray(li), np.asarray(fi)))
        if interpret:
            ks, ki = ops.fused_query(qt[:4], qm[:4], psi, cents, ids, v, s,
                                     nprobe=nprobe, kp=kp, use_kernel=True)
            parity &= bool(np.array_equal(np.asarray(ki), np.asarray(li[:4])))
            finite = np.isfinite(np.asarray(ls[:4]))
            tol = 1e-5 if s is None else 2 ** -13
            parity &= bool(np.allclose(np.asarray(ks)[finite],
                                       np.asarray(ls[:4])[finite],
                                       rtol=tol, atol=1e-3))
        flops = (2 * B * Tq * d * dp            # in-kernel psi projection
                 + 2 * B * nlist * dp           # probe-select prelude
                 + 2 * B * nprobe * cap * dp)   # probe scan
        gathered = (B * nprobe * cap * (dp * item + 4
                                        + (4 if s is not None else 0))
                    + B * Tq * d * 4 + d * dp * 4)
        rows.append(common.bench_row(
            name, f"B={B},Tq={Tq},d={d},dp={dp},nprobe={nprobe},"
                  f"cap={cap},kp={kp}",
            common.timeit(legacy, qt, qm), common.timeit(fused, qt, qm),
            gathered, parity=parity, flops=flops,
            launches={"legacy": 3, "fused": 1}))
        common.emit(f"kernel_{name}", rows[-1]["fused_us"],
                    f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy_3launch")
    return rows


def run(emit_json: bool = False):
    rng = np.random.default_rng(0)
    out = {}
    # token_maxsim (rerank/OLS-target contraction)
    x = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((2048, 24, 128)), jnp.float32)
    mask = jnp.ones((2048, 24), bool)
    f = jax.jit(lambda a, b, c: ref.token_maxsim_ref(a, b, c))
    t = common.timeit(f, x, docs, mask)
    flops = 2 * 512 * 2048 * 24 * 128
    out["token_maxsim"] = {"s": t, "gflops": flops / t / 1e9}
    common.emit("kernel_token_maxsim", t * 1e6, f"gflops={flops/t/1e9:.1f}")

    # fused_psi
    k = jnp.asarray(rng.standard_normal((128, 2048)) * 0.05, jnp.float32)
    b = jnp.zeros(2048); g = jnp.ones(2048); beta = jnp.zeros(2048)
    xx = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    f = jax.jit(lambda a: ref.fused_psi_ref(a, k, b, g, beta))
    t = common.timeit(f, xx)
    out["fused_psi"] = {"s": t}
    common.emit("kernel_fused_psi", t * 1e6, "n=4096,d128->2048")

    # mips_sq8 scan
    corpus = jnp.asarray(rng.standard_normal((65536, 128)), jnp.float32)
    codes, scales = sq8_quant(corpus)
    qv = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    f = jax.jit(lambda a: ref.mips_sq8_ref(a, codes, scales))
    t = common.timeit(f, qv)
    flops = 2 * 64 * 65536 * 128
    out["mips_sq8"] = {"s": t, "gflops": flops / t / 1e9}
    common.emit("kernel_mips_sq8", t * 1e6, f"gflops={flops/t/1e9:.1f}")

    gather = _gather_rows(rng)
    out["gather"] = gather
    common.save_json("kernels", out)
    regressions = []
    if emit_json:
        meta = {"backend": jax.default_backend(),
                "device_kind": jax.devices()[0].device_kind,
                "jax_version": jax.__version__,
                "seed": 0,
                "note": "fused rows run the real ops dispatch — on CPU "
                        "both paths lower to jnp (ratio ~1); the "
                        "gather-at-source / one-launch wins land on TPU"}
        doc, regressions = _merge_bench_root(meta, gather)
        common.save_bench_root("kernels", doc)
    bad = [r["op"] for r in gather if not r["parity"]]
    if bad:
        raise SystemExit(f"fused-path parity regression in: {bad}")
    if regressions:
        raise SystemExit("roofline_frac regression vs checked-in "
                         "BENCH_kernels.json: " + "; ".join(regressions))
    return out


def _merge_bench_root(meta: dict, rows: list[dict]):
    """Merge freshly measured rows into the committed BENCH_kernels.json.

    * rows this run did NOT re-measure are preserved verbatim (same
      semantics as PR 5's ``"online"`` section: a kernels-only run must not
      drop the serving rows, a CPU run must not drop TPU rows) — a row's
      identity is (op, shape, backend);
    * the roofline ratchet: a re-measured row whose ``roofline_frac`` fell
      more than ``REPRO_BENCH_ROOFLINE_TOL`` (default 10%) below the
      checked-in row for the SAME identity is reported as a regression (the
      caller SystemExits after writing, so the artifact still shows the
      offending numbers).  CPU timing is noisy — CI's cpu-runner smoke sets
      a looser tolerance; TPU runs keep the strict default."""
    import json
    import os

    path = common.REPO_ROOT / "BENCH_kernels.json"
    prev = json.loads(path.read_text()) if path.exists() else {}
    prev_backend = prev.get("meta", {}).get("backend")

    def key(r, fallback):
        return (r["op"], r["shape"], r.get("backend", fallback))

    prev_rows = {key(r, prev_backend): r for r in prev.get("rows", [])}
    tol = float(os.environ.get("REPRO_BENCH_ROOFLINE_TOL", "0.10"))
    regressions = []
    for r in rows:
        old = prev_rows.get(key(r, meta["backend"]))
        if not old or "roofline_frac" not in old or "roofline_frac" not in r:
            continue
        if r["roofline_frac"] < old["roofline_frac"] * (1.0 - tol):
            regressions.append(
                f"{r['op']}[{r['shape']}] "
                f"{old['roofline_frac']:.4g} -> {r['roofline_frac']:.4g}")
    fresh = {key(r, meta["backend"]) for r in rows}
    merged = list(rows) + [r for kk, r in prev_rows.items()
                           if kk not in fresh]
    doc = {k: v for k, v in prev.items() if k not in ("meta", "rows")}
    doc["meta"] = meta
    doc["rows"] = merged
    return doc, regressions


if __name__ == "__main__":
    import argparse

    _p = argparse.ArgumentParser()
    _p.add_argument("--emit-json", action="store_true",
                    help="also overwrite the committed repo-root "
                         "BENCH_kernels.json (the perf trajectory)")
    run(emit_json=_p.parse_args().emit_json)
