"""Kernel-layer micro-benchmarks: ops-vs-ref wall time (CPU: reference path
is the measurement; the Pallas path is TPU-targeted and validated in
interpret mode by tests).  Reports the arithmetic layout costs that drive
the §Perf napkin math."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.anns.quantization import sq8_quant
from repro.kernels import ref


def run():
    rng = np.random.default_rng(0)
    out = {}
    # token_maxsim (rerank/OLS-target contraction)
    x = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((2048, 24, 128)), jnp.float32)
    mask = jnp.ones((2048, 24), bool)
    f = jax.jit(lambda a, b, c: ref.token_maxsim_ref(a, b, c))
    t = common.timeit(f, x, docs, mask)
    flops = 2 * 512 * 2048 * 24 * 128
    out["token_maxsim"] = {"s": t, "gflops": flops / t / 1e9}
    common.emit("kernel_token_maxsim", t * 1e6, f"gflops={flops/t/1e9:.1f}")

    # fused_psi
    k = jnp.asarray(rng.standard_normal((128, 2048)) * 0.05, jnp.float32)
    b = jnp.zeros(2048); g = jnp.ones(2048); beta = jnp.zeros(2048)
    xx = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    f = jax.jit(lambda a: ref.fused_psi_ref(a, k, b, g, beta))
    t = common.timeit(f, xx)
    out["fused_psi"] = {"s": t}
    common.emit("kernel_fused_psi", t * 1e6, "n=4096,d128->2048")

    # mips_sq8 scan
    corpus = jnp.asarray(rng.standard_normal((65536, 128)), jnp.float32)
    codes, scales = sq8_quant(corpus)
    qv = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    f = jax.jit(lambda a: ref.mips_sq8_ref(a, codes, scales))
    t = common.timeit(f, qv)
    flops = 2 * 64 * 65536 * 128
    out["mips_sq8"] = {"s": t, "gflops": flops / t / 1e9}
    common.emit("kernel_mips_sq8", t * 1e6, f"gflops={flops/t/1e9:.1f}")

    common.save_json("kernels", out)
    return out


if __name__ == "__main__":
    run()
