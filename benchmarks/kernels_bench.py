"""Kernel-layer micro-benchmarks: ops-vs-ref wall time (CPU: reference path
is the measurement; the Pallas path is TPU-targeted and validated in
interpret mode by tests).  Reports the arithmetic layout costs that drive
the §Perf napkin math, plus the fused-vs-legacy gather rows that feed the
repo-root ``BENCH_kernels.json`` perf trajectory."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.anns.quantization import sq8_quant
from repro.kernels import ops, ref


def _gather_rows(rng):
    """Fused-vs-legacy rows at the raw kernel/oracle level: the probe-scan
    and rerank score stages, stripped of index build and top-k, through the
    real ``ops`` dispatch (parity asserted per row).

    ``REPRO_BENCH_INTERPRET=1`` (the CI bench-smoke job) additionally runs
    the Pallas kernels in interpret mode on a small slice and folds the
    result into each row's parity bit — a kernel-body regression fails the
    bench even on a CPU runner."""
    import os

    from repro.core import maxsim

    interpret = os.environ.get("REPRO_BENCH_INTERPRET") == "1"
    rows = []
    # IVF probe scan stage: (B, nprobe) clusters of (cap, d)
    B, nlist, cap, d, nprobe = 64, 128, 128, 128, 16
    ids = jnp.asarray(rng.integers(0, 1 << 20, (nlist, cap)), jnp.int32)
    vecs = jnp.asarray(rng.standard_normal((nlist, cap, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    probe = jnp.asarray(rng.integers(0, nlist, (B, nprobe)), jnp.int32)
    codes, scales = sq8_quant(vecs)
    for name, v, s, item in (("ivf_scan_fp32", vecs, None, 4),
                             ("ivf_scan_sq8", codes, scales, 1)):
        legacy = jax.jit(lambda qq, pp, v=v, s=s: ref.ivf_scan_ref(
            qq, pp, ids, v, s))
        fused = jax.jit(lambda qq, pp, v=v, s=s: ops.fused_ivf_scan(
            qq, pp, ids, v, s))
        lo, fo = legacy(q, probe), fused(q, probe)
        parity = bool(np.allclose(np.asarray(lo), np.asarray(fo)))
        if interpret:
            ko = ops.fused_ivf_scan(q[:4], probe[:4], ids, v, s,
                                    use_kernel=True)
            tol = 1e-6 if s is None else 2 ** -13
            parity &= bool(np.allclose(np.asarray(ko), np.asarray(lo[:4]),
                                       rtol=tol, atol=1e-3))
        gathered = B * nprobe * cap * (d * item + 4 + (4 if s is not None else 0))
        rows.append(common.bench_row(
            name, f"B={B},nprobe={nprobe},cap={cap},d={d}",
            common.timeit(legacy, q, probe), common.timeit(fused, q, probe),
            gathered, parity=parity))
        common.emit(f"kernel_{name}", rows[-1]["fused_us"],
                    f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy")

    # candidate-gather rerank stage: (B, k') docs of (Td, d)
    B, m, Tq, Td, d, kp = 32, 8192, 8, 16, 128, 128
    qt = jnp.asarray(rng.standard_normal((B, Tq, d)), jnp.float32)
    qm = jnp.ones((B, Tq), bool)
    docs = jnp.asarray(rng.standard_normal((m, Td, d)), jnp.float32)
    dm = jnp.asarray(rng.random((m, Td)) > 0.2).at[:, 0].set(True)
    cand = jnp.asarray(rng.integers(0, m, (B, kp)), jnp.int32)
    legacy = jax.jit(lambda a, b, c: maxsim.rerank(a, b, c, docs, dm, 10))
    fused = jax.jit(lambda a, b, c: ops.fused_rerank(a, b, c, docs, dm, 10))
    _, li = legacy(qt, qm, cand)
    _, fi = fused(qt, qm, cand)
    parity = bool(np.array_equal(np.asarray(li), np.asarray(fi)))
    if interpret:
        _, ki = ops.fused_rerank(qt[:2], qm[:2], cand[:2], docs, dm, 10,
                                 use_kernel=True)
        parity &= bool(np.array_equal(np.asarray(ki), np.asarray(li[:2])))
    rows.append(common.bench_row(
        "rerank", f"B={B},k_prime={kp},Tq={Tq},Td={Td},d={d}",
        common.timeit(legacy, qt, qm, cand), common.timeit(fused, qt, qm, cand),
        B * kp * Td * (d * 4 + 4), parity=parity))
    common.emit("kernel_rerank_fused", rows[-1]["fused_us"],
                f"x{rows[-1]['fused_vs_legacy']:.2f}_vs_legacy")
    return rows


def run(emit_json: bool = False):
    rng = np.random.default_rng(0)
    out = {}
    # token_maxsim (rerank/OLS-target contraction)
    x = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)
    docs = jnp.asarray(rng.standard_normal((2048, 24, 128)), jnp.float32)
    mask = jnp.ones((2048, 24), bool)
    f = jax.jit(lambda a, b, c: ref.token_maxsim_ref(a, b, c))
    t = common.timeit(f, x, docs, mask)
    flops = 2 * 512 * 2048 * 24 * 128
    out["token_maxsim"] = {"s": t, "gflops": flops / t / 1e9}
    common.emit("kernel_token_maxsim", t * 1e6, f"gflops={flops/t/1e9:.1f}")

    # fused_psi
    k = jnp.asarray(rng.standard_normal((128, 2048)) * 0.05, jnp.float32)
    b = jnp.zeros(2048); g = jnp.ones(2048); beta = jnp.zeros(2048)
    xx = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    f = jax.jit(lambda a: ref.fused_psi_ref(a, k, b, g, beta))
    t = common.timeit(f, xx)
    out["fused_psi"] = {"s": t}
    common.emit("kernel_fused_psi", t * 1e6, "n=4096,d128->2048")

    # mips_sq8 scan
    corpus = jnp.asarray(rng.standard_normal((65536, 128)), jnp.float32)
    codes, scales = sq8_quant(corpus)
    qv = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    f = jax.jit(lambda a: ref.mips_sq8_ref(a, codes, scales))
    t = common.timeit(f, qv)
    flops = 2 * 64 * 65536 * 128
    out["mips_sq8"] = {"s": t, "gflops": flops / t / 1e9}
    common.emit("kernel_mips_sq8", t * 1e6, f"gflops={flops/t/1e9:.1f}")

    gather = _gather_rows(rng)
    out["gather"] = gather
    common.save_json("kernels", out)
    if emit_json:
        common.save_bench_root("kernels", {
            "meta": {"backend": jax.default_backend(),
                     "note": "fused rows run the real ops dispatch — on CPU "
                             "both paths lower to jnp (ratio ~1); the "
                             "gather-at-source wins land on TPU"},
            "rows": gather})
    bad = [r["op"] for r in gather if not r["parity"]]
    if bad:
        raise SystemExit(f"fused-path parity regression in: {bad}")
    return out


if __name__ == "__main__":
    import argparse

    _p = argparse.ArgumentParser()
    _p.add_argument("--emit-json", action="store_true",
                    help="also overwrite the committed repo-root "
                         "BENCH_kernels.json (the perf trajectory)")
    run(emit_json=_p.parse_args().emit_json)
