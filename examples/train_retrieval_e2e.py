"""End-to-end driver: train a ~100M-parameter multi-vector ENCODER for a few
hundred steps (contrastive MaxSim objective), then index its token embeddings
with LEMUR and serve queries — the full train->index->serve lifecycle of a
multi-vector retrieval system.

The encoder is a small decoder-stack LM (the same repro.models.lm used by the
assigned archs) read out at every position, ColBERT-style.

  PYTHONPATH=src python examples/train_retrieval_e2e.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LemurConfig, build_index, maxsim, recall_at
from repro.core.index import query
from repro.models import lm
from repro.optim import adam_init, adam_update


def make_encoder_cfg(d_model=256, n_layers=8, vocab=8192):
    # ~100M-class config scaled for the CPU budget (n_layers*12*d^2 + vocab*d)
    return lm.LMConfig(n_layers=n_layers, d_model=d_model, n_heads=8, n_kv_heads=8,
                       head_dim=d_model // 8, d_ff=4 * d_model, vocab=vocab,
                       q_block=32, kv_block=32, loss_chunk=32, remat="none")


def encode(params, tokens, cfg):
    """Per-token unit-norm embeddings (late-interaction representation)."""
    h, _ = lm.forward_train(params, tokens, cfg)
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)


def maxsim_logits(qe, de):
    """(B, Tq, d) x (B, Td, d) -> (B, B) in-batch MaxSim score matrix."""
    s = jnp.einsum("bqd,ctd->bcqt", qe, de)
    return jnp.max(s, axis=-1).sum(axis=-1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    cfg = make_encoder_cfg()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"encoder params: {n_params/1e6:.1f}M")
    opt = adam_init(params)

    rng = np.random.default_rng(0)
    # synthetic paired data: queries are noisy prefixes of their documents
    def batch(seed):
        r = np.random.default_rng(seed)
        docs = r.integers(0, cfg.vocab, (args.batch, 24)).astype(np.int32)
        qs = docs[:, :8].copy()
        flip = r.random((args.batch, 8)) < 0.1
        qs[flip] = r.integers(0, cfg.vocab, flip.sum())
        return jnp.asarray(qs), jnp.asarray(docs)

    @jax.jit
    def step(params, opt, qt, dt):
        def loss_fn(p):
            qe = encode(p, qt, cfg)
            de = encode(p, dt, cfg)
            logits = maxsim_logits(qe, de) / 0.5
            labels = jnp.arange(qt.shape[0])
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
            return jnp.mean(lse - gold)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, m = adam_update(grads, opt, params, lr=3e-4, grad_clip=1.0)
        return params, opt, loss

    t0 = time.time()
    for i in range(args.steps):
        qt, dt = batch(i)
        params, opt, loss = step(params, opt, qt, dt)
        if (i + 1) % 50 == 0:
            print(f"step {i+1}/{args.steps} contrastive loss {float(loss):.4f} "
                  f"({(i+1)/(time.time()-t0):.1f} steps/s)")

    # ---- index the encoder's corpus embeddings with LEMUR ----
    m_docs = 2000
    doc_tok_ids = jnp.asarray(rng.integers(0, cfg.vocab, (m_docs, 24)), jnp.int32)
    de = np.asarray(encode(params, doc_tok_ids, cfg))

    class Corpus:
        doc_tokens = de.astype(np.float32)
        doc_mask = np.ones(de.shape[:2], bool)
        d = de.shape[-1]
        m = m_docs
        centers = np.zeros((1, de.shape[-1]), np.float32)

    lcfg = LemurConfig(d=cfg.d_model, d_prime=128, m_pretrain=512, n_train=8192,
                       n_ols=2048, epochs=10, k=10, k_prime=128,
                       query_strategy="corpus")
    index = build_index(jax.random.PRNGKey(1), Corpus, lcfg, verbose=True)

    # queries = encoded prefixes of a sample of docs
    qids = rng.integers(0, m_docs, 32)
    q = encode(params, doc_tok_ids[qids, :8], cfg)
    qm = jnp.ones(q.shape[:2], bool)
    _, truth = maxsim.true_topk(q, qm, index.doc_tokens, index.doc_mask, 10)
    _, got = query(index, q, qm)
    rec = float(recall_at(got, truth).mean())
    self_hit = float((got[:, 0] == jnp.asarray(qids)).mean())
    print(f"LEMUR over trained encoder: recall@10={rec:.3f}, "
          f"query->own-doc top-1 rate={self_hit:.2f}")


if __name__ == "__main__":
    main()
