"""Fleet serving demo: replicated router, deadlines, SLO-adaptive search.

Where ``serve_online.py`` runs ONE micro-batching server, this demo fronts
N replicas of the same retriever with ``repro.fleet.Router`` and exercises
the fleet semantics end to end:

* **dispatch + parity** — least-outstanding-requests routing; sampled fleet
  answers are re-checked bit-identical against a direct facade search;
* **deadlines + admission control** — every request carries a deadline and
  the router's outstanding-request bound turns excess load into typed
  ``Overloaded`` rejects instead of unbounded queueing;
* **SLO-adaptive search** — an ``SLOController`` watches the windowed p99
  and walks ``SearchParams`` down a pre-compiled rung ladder (smaller
  ``nprobe``/``k_prime``) under sustained breach, with hysteretic recovery;
* **snapshot-consistent add** — one ``add()`` fans out to every replica
  behind a write barrier: the aggregate resolves only when ALL replicas
  sit at the same ``snapshot_version``, and a post-add query retrieves the
  new document on whichever replica answers;
* **chaos** — a replica is wedged mid-traffic; the health monitor
  quarantines it and re-homes its in-flight requests (nothing lost).

  PYTHONPATH=src python examples/serve_fleet.py
  PYTHONPATH=src python examples/serve_fleet.py --replicas 3 --rate 2000
"""
import argparse
import time

import jax
import numpy as np

from repro.core import LemurConfig
from repro.data import synthetic
from repro.fleet import (
    Router,
    SLOController,
    build_rungs,
    clone_replicas,
    warm_replicas,
)
from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams
from repro.serving import BucketLadder, poisson_trace, ragged_queries, replay

p = argparse.ArgumentParser()
p.add_argument("--m", type=int, default=2000)
p.add_argument("--replicas", type=int, default=2)
p.add_argument("--rate", type=float, default=1000.0,
               help="offered load for the overload phase, queries/second")
p.add_argument("--duration", type=float, default=4.0)
p.add_argument("--deadline-ms", type=float, default=250.0)
p.add_argument("--queue-depth", type=int, default=48)
args = p.parse_args()

d = 32
corpus = synthetic.make_corpus(m=args.m, d=d, avg_tokens=12, max_tokens=16,
                               seed=0)
cfg = LemurConfig(d=d, d_prime=64, m_pretrain=512, n_train=8192, n_ols=2048,
                  epochs=10, k=10, k_prime=128, anns="ivf",
                  ivf=IVFBackendConfig(nprobe=16))
retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0),
                                 verbose=True)

ladder = BucketLadder((8, 16, 32), max_batch=8)
queries = ragged_queries(256, d, tq_range=(2, 24), seed=1)
reps = clone_replicas(retriever, args.replicas)
rungs = build_rungs(retriever)
print(f"\nfleet: {args.replicas} replicas, rung ladder "
      f"{[(r.k_prime, getattr(r.backend, 'nprobe', None)) for r in rungs]}")
warmed = warm_replicas(reps, ladder, d, params_list=rungs)
print(f"warmed {warmed} shapes "
      f"(= replicas x ladder.compile_bound({len(rungs)}))")

# phase 1: light traffic — parity + balanced dispatch --------------------
with Router(reps, ladder=ladder, max_queue_depth=args.queue_depth,
            default_deadline_s=args.deadline_ms / 1e3,
            stall_timeout_s=60.0) as router:
    futs = [router.submit(q) for q in queries[:32]]
    served = set()
    for f, q in zip(futs, queries[:32]):
        _, ids = f.result(timeout=120)
        _, want = retriever.search(q[None], np.ones((1, len(q)), bool))
        assert np.array_equal(ids, np.asarray(want)[0]), "parity broke"
        served.add(f.replica)
    print(f"\n[1] parity ok over 32 requests, served by replicas {sorted(served)}")

    # phase 2: snapshot-consistent add ----------------------------------
    grow = synthetic.make_corpus(m=4, d=d, avg_tokens=12, max_tokens=16,
                                 seed=7)
    af = router.add(grow.doc_tokens, grow.doc_mask)
    new_m = af.result(timeout=300)
    probe = np.asarray(grow.doc_tokens[0][grow.doc_mask[0]])
    f = router.submit(probe, params=SearchParams(use_ann=False, k_prime=new_m))
    _, ids = f.result(timeout=120)
    print(f"[2] add barrier: m {args.m} -> {new_m}, every replica at "
          f"snapshot {af.snapshot_version}; post-add probe found doc "
          f"{int(ids[0])} (expected {args.m}) on replica {f.replica}")

# the add grew the corpus, so every compiled shape is stale — re-warm
# outside the serving path so phases 3/4 measure serving, not XLA compiles
# (and the chaos phase's tight stall timeout doesn't mistake a multi-second
# recompile for a wedged replica)
warm_replicas(reps, ladder, d, params_list=rungs)

# phase 3: overload — SLO downshift + typed rejects ----------------------
slo = SLOController(rungs, target_p99_ms=25.0, window=64, min_window=16,
                    eval_every=16)
arrivals = poisson_trace(args.rate, args.duration, seed=2)
with Router(reps, ladder=ladder, max_queue_depth=args.queue_depth,
            default_deadline_s=args.deadline_ms / 1e3, slo=slo,
            stall_timeout_s=60.0) as router:
    _, report = replay(router, queries, arrivals)
    print(f"\n[3] overload at {args.rate:g} qps for {args.duration:g}s: "
          f"p50={report['p50_ms']:.1f}ms p99={report['p99_ms']:.1f}ms "
          f"achieved={report['qps']:.0f}qps rejected={report['n_rejected']} "
          f"expired={report['n_expired']} lost={report['n_lost']}")
    for tr in slo.transitions:
        print(f"    slo {tr.direction}: rung {tr.from_rung} -> {tr.to_rung} "
              f"(windowed p99 {tr.p99_ms:.1f}ms vs target {tr.target_ms:.1f}ms)")
    print(f"    final rung {slo.rung}/{len(rungs) - 1}")

# phase 4: chaos — wedge a replica, watch the quarantine -----------------
with Router(reps, ladder=ladder, max_queue_depth=None,
            stall_timeout_s=0.4, health_interval_s=0.05) as router:
    router.servers[0].pause()          # wedge replica 0 mid-traffic
    futs = [router.submit(q) for q in queries[:12]]
    for f in futs:
        f.result(timeout=120)          # all complete despite the wedge
    time.sleep(0.1)
    print(f"\n[4] chaos: wedged replica 0 -> quarantined={router.quarantined()} "
          f"healthy={router.n_healthy}/{args.replicas}, all 12 in-flight "
          f"requests re-homed and completed")
    for ev in router.events():
        print(f"    event: {ev}")
