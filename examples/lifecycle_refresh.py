"""Learned-index lifecycle demo: drift -> background refresh -> warm swap.

A LEMUR index is a *fit*: the OLS latent map and the IVF centroids are
optimal for the corpus they were built on.  Stream in enough
distribution-shifted documents and first-stage recall silently decays —
nothing errors, results just get worse.  This demo walks the closed loop
that repairs it, then injects a fault to show the failure contract:

1. serve a built index and feed the ``DriftMonitor`` an in-distribution
   trickle: the coverage signal stays near baseline, NO trigger;
2. add a topic-shifted burst: first-stage self-retrieval coverage of the
   new docs collapses and the monitor trips with a typed ``DriftReport``;
3. a chaos-injected refresh dies mid-rebuild: serving is bit-identically
   untouched, the manager records ``RefreshFailed`` and retries;
4. the retry re-fits W + re-clusters IVF off-thread and warm-swaps through
   the server's FIFO barrier: searches submitted before the swap answer
   from the old snapshot (stamped with its version), later ones from the
   refit index, zero requests dropped.

  PYTHONPATH=src python examples/lifecycle_refresh.py
"""
import jax
import numpy as np

from repro.core import LemurConfig
from repro.data import synthetic
from repro.lifecycle import ChaosInjector, DriftMonitor, LifecycleManager
from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams
from repro.serving import BucketLadder, RetrieverServer

M, D = 600, 32
corpus = synthetic.make_corpus(m=M, d=D, avg_tokens=12, max_tokens=16, seed=0)
cfg = LemurConfig(d=D, d_prime=64, m_pretrain=256, n_train=4096, n_ols=1024,
                  epochs=4, k=10, k_prime=128, anns="ivf",
                  ivf=IVFBackendConfig(nprobe=16))
retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0),
                                 verbose=True)

chaos = ChaosInjector()
chaos.fail_at("refresh:refit")          # kill the FIRST rebuild mid-train

with RetrieverServer(retriever, ladder=BucketLadder((8, 16), max_batch=8),
                     max_wait_us=2000) as srv:
    # the trigger threshold is an operating knob: this corpus' in-dist
    # coverage ratio sits around 0.7 of baseline, the burst's around 0.4,
    # so trigger halfway; probe the whole reservoir for a stable read
    monitor = DriftMonitor(retriever, seed=0, probe_docs=192,
                           coverage_ratio_threshold=0.55)
    mgr = LifecycleManager(srv, monitor=monitor, seed=1, chaos=chaos,
                           cooldown_s=0.0, min_reservoir=64)
    mgr.start(auto=False)               # manual polling, so the demo narrates

    # -- 1. in-distribution adds: the monitor stays quiet ------------------
    indist = synthetic.make_corpus(m=M + 96, d=D, avg_tokens=12,
                                   max_tokens=16, seed=0)
    fa = srv.add(indist.doc_tokens[M:], indist.doc_mask[M:])
    fa.result(timeout=300)
    report = monitor.report()
    print(f"in-dist adds : coverage={report.coverage:.3f} "
          f"(baseline {report.baseline_coverage:.3f})  "
          f"triggered={report.triggered}")
    assert not report.triggered

    # -- 2. topic-shifted burst: coverage collapses, the monitor trips -----
    # the in-distribution docs churn away (a delete also drops them from
    # the monitor's reservoir), so RECENT mutations are burst-dominated
    burst = synthetic.make_corpus(m=192, d=D, avg_tokens=12, max_tokens=16,
                                  n_centers=6, topic_strength=4.0, seed=777)
    srv.add(burst.doc_tokens, burst.doc_mask).result(timeout=300)
    srv.delete(np.asarray(fa.added_ids)).result(timeout=300)
    srv.delete(np.arange(96)).result(timeout=300)
    report = monitor.report()
    print(f"topic burst  : coverage={report.coverage:.3f} -> "
          f"triggered={report.triggered}  ({report.reason})")
    assert report.triggered

    # -- 3. chaos kills the first refresh: serving untouched, typed event --
    q = np.asarray(burst.doc_tokens[0][burst.doc_mask[0]], np.float32)
    pre = srv.submit(q)
    v0 = retriever.version
    ok = mgr.poll_once()
    failed = mgr.events()[-1]
    print(f"chaos refresh: swap_completed={ok}  last_event={failed.kind}"
          f"(phase={getattr(failed, 'phase', '?')})  "
          f"version still {retriever.version}")
    assert not ok and retriever.version == v0

    # -- 4. the retry succeeds and warm-swaps behind the FIFO barrier ------
    ok = mgr.poll_once()
    s, ids = pre.result(timeout=300)
    print(f"retry        : swap_completed={ok}  "
          f"version {v0} -> {retriever.version}  "
          f"pre-swap future answered by snapshot v{pre.snapshot_version}")
    assert ok and retriever.version == v0 + 1 and pre.snapshot_version <= v0

    _, post_ids = srv.search(q, params=SearchParams(k=10, k_prime=128),
                             timeout=300)
    print(f"post-swap    : top-1 for a burst-doc query = doc "
          f"{int(post_ids[0])} (burst slots start at {M + 96})")

    print("\nevent log:")
    for ev in mgr.events():
        print(f"  {ev.kind:>16}: {ev}")
    mgr.stop()
print("done")
