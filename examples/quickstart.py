"""Quickstart: build a LEMUR index on a synthetic multi-vector corpus and
retrieve with the full Fig. 1 pipeline — ψ pooling -> latent ANN -> exact
MaxSim rerank.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import LemurConfig, build_index, maxsim, recall_at
from repro.core.index import query
from repro.data import synthetic

# 1. a corpus of multi-vector documents (sets of unit-norm token embeddings)
corpus = synthetic.make_corpus(m=3000, d=32, avg_tokens=12, max_tokens=16, seed=0)

# 2. LEMUR: learn ψ against m' sampled docs, fit W rows by OLS, index W
cfg = LemurConfig(d=32, d_prime=192, m_pretrain=768, n_train=12288, n_ols=3072,
                  epochs=30, k=10, k_prime=256, anns="ivf", ivf_nprobe=48)
index = build_index(jax.random.PRNGKey(0), corpus, cfg, verbose=True)

# 3. query (corpus-query strategy mirrors the paper's default)
q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 32, q_tokens=8, seed=1))
q_mask = jnp.ones(q.shape[:2], bool)
scores, doc_ids = query(index, q, q_mask)

# 4. evaluate against exact MaxSim ground truth
_, truth = maxsim.true_topk(q, q_mask, index.doc_tokens, index.doc_mask, cfg.k)
print(f"recall@{cfg.k}: {float(recall_at(doc_ids, truth).mean()):.3f}")
print("top-3 docs for query 0:", doc_ids[0, :3].tolist(),
      "scores:", [round(float(s), 3) for s in scores[0, :3]])
