"""Quickstart: build a LEMUR retriever on a synthetic multi-vector corpus
and retrieve with the full Fig. 1 pipeline — ψ pooling -> latent ANN ->
exact MaxSim rerank — through the stable Retriever API v1 facade, then
round-trip it through save/load.

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --m 800 --epochs 8   # CI smoke
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LemurConfig, maxsim, recall_at
from repro.data import synthetic
from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams

p = argparse.ArgumentParser()
p.add_argument("--m", type=int, default=3000, help="corpus size")
p.add_argument("--epochs", type=int, default=30, help="psi pretrain epochs")
args = p.parse_args()

# 1. a corpus of multi-vector documents (sets of unit-norm token embeddings)
corpus = synthetic.make_corpus(m=args.m, d=32, avg_tokens=12, max_tokens=16, seed=0)

# 2. LEMUR: learn ψ against m' sampled docs, fit W rows by OLS, index W.
#    Backend knobs live in per-backend config namespaces (cfg.ivf, ...).
cfg = LemurConfig(d=32, d_prime=192, m_pretrain=768, n_train=12288, n_ols=3072,
                  epochs=args.epochs, k=10, k_prime=256, anns="ivf",
                  ivf=IVFBackendConfig(nprobe=48))
retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0), verbose=True)

# 3. query (corpus-query strategy mirrors the paper's default); every
#    query-time knob is a typed, jit-static SearchParams
q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 32, q_tokens=8, seed=1))
q_mask = jnp.ones(q.shape[:2], bool)
params = SearchParams(k=10)
scores, doc_ids = retriever.search(q, q_mask, params)

# 4. evaluate against exact MaxSim ground truth
idx = retriever.index
_, truth = maxsim.true_topk(q, q_mask, idx.doc_tokens, idx.doc_mask, cfg.k)
print(f"recall@{cfg.k}: {float(recall_at(doc_ids, truth).mean()):.3f}")
print("top-3 docs for query 0:", doc_ids[0, :3].tolist(),
      "scores:", [round(float(s), 3) for s in scores[0, :3]])

# 5. persistence: save/load reproduces the search ids bit-identically
with tempfile.TemporaryDirectory() as d:
    retriever.save(d)
    reloaded = LemurRetriever.load(d)
    _, ids2 = reloaded.search(q, q_mask, params)
    assert (np.asarray(ids2) == np.asarray(doc_ids)).all()
    print(f"save/load round-trip OK ({reloaded!r}, "
          f"jit traces after reload: {reloaded.trace_count(params)})")
