"""Run one training step of EVERY assigned architecture's reduced config —
the `--arch` selector demonstration.

  PYTHONPATH=src python examples/multi_arch_smoke.py [--arch qwen2.5-32b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_arch
from repro.optim import adam_init


def run_one(arch: str):
    from repro.data import synthetic

    mod = get_arch(arch)
    cfg = mod.SMOKE
    if mod.FAMILY == "lm":
        from repro.models import lm

        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
        step = jax.jit(lm.make_train_step(cfg))
        p, o, m = step(params, adam_init(params), {"tokens": toks, "labels": toks})
    elif mod.FAMILY == "gnn":
        from repro.models import gnn

        g = synthetic.make_mesh_graph(200, d_feat=cfg.d_node_in, d_edge=cfg.d_edge_in,
                                      d_out=cfg.d_out)
        params = gnn.init_gnn(jax.random.PRNGKey(0), cfg)
        b = {"node_feat": jnp.asarray(g.node_feat), "edge_feat": jnp.asarray(g.edge_feat),
             "senders": jnp.asarray(g.senders), "receivers": jnp.asarray(g.receivers),
             "labels": jnp.asarray(g.labels)}
        p, o, m = jax.jit(gnn.make_train_step(cfg))(params, adam_init(params), b)
    elif mod.FAMILY == "recsys":
        from repro.models import recsys

        params = recsys.init_recsys(jax.random.PRNGKey(0), cfg)
        d = synthetic.make_clicks(32, max(cfg.n_fields, 1),
                                  np.array(cfg.vocab_sizes or [10]),
                                  hist_len=cfg.seq_len, n_items=cfg.n_items)
        if cfg.model == "bst":
            b = {"history": jnp.asarray(d["history"]),
                 "target_item": jnp.asarray(d["target_item"]),
                 "labels": jnp.asarray(d["labels"])}
        elif cfg.model == "two_tower":
            b = {"ids": jnp.asarray(d["ids"][:, :cfg.n_fields]),
                 "item": jnp.asarray(d["target_item"]),
                 "labels": jnp.asarray(d["labels"])}
        else:
            b = {"ids": jnp.asarray(d["ids"][:, :cfg.n_fields]),
                 "labels": jnp.asarray(d["labels"])}
        p, o, m = jax.jit(recsys.make_train_step(cfg))(params, adam_init(params), b)
    else:
        print(f"  {arch}: (lemur — see quickstart.py)")
        return
    print(f"  {arch:28s} loss={float(m['loss']):.4f} grad_norm={float(m['grad_norm']):.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    args = ap.parse_args()
    targets = [args.arch] if args.arch else [a for a in ARCHS if a != "lemur"]
    print("one reduced-config train step per architecture:")
    for a in targets:
        run_one(a)
