"""Online serving demo: ragged queries, micro-batching, streaming add.

Where ``serve_batched.py`` times fixed-shape offline slabs, this demo runs
the ONLINE path end to end: a ``RetrieverServer`` in front of the facade,
fed a Poisson trace of ragged single queries (the workload the paper's
"order of magnitude faster online" claim is about), with a streaming
``add()`` landing mid-traffic:

* requests are padded onto the Tq bucket ladder and coalesced into
  micro-batches (``max_batch``/``max_wait_us``), so the number of compiled
  XLA graphs stays within ``ladder.compile_bound()`` forever;
* ``add()`` is a FIFO barrier — earlier queries answer from the old corpus
  snapshot, the swap is atomic between micro-batches, and a post-add query
  provably retrieves a just-added document;
* the report shows the latency/occupancy tradeoff knobs.

  PYTHONPATH=src python examples/serve_online.py
  PYTHONPATH=src python examples/serve_online.py --rate 300 --max-wait-us 5000
"""
import argparse

import jax
import numpy as np

from repro.core import LemurConfig
from repro.data import synthetic
from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams
from repro.serving import (
    BucketLadder,
    RetrieverServer,
    poisson_trace,
    ragged_queries,
    replay,
    warm_buckets,
)

p = argparse.ArgumentParser()
p.add_argument("--m", type=int, default=4000)
p.add_argument("--rate", type=float, default=150.0,
               help="offered load, queries/second")
p.add_argument("--duration", type=float, default=6.0)
p.add_argument("--max-batch", type=int, default=8)
p.add_argument("--max-wait-us", type=int, default=2000,
               help="head-of-line budget: higher -> fuller batches, "
                    "higher p50")
args = p.parse_args()

corpus = synthetic.make_corpus(m=args.m, d=32, avg_tokens=12, max_tokens=16,
                               seed=0)
cfg = LemurConfig(d=32, d_prime=64, m_pretrain=512, n_train=8192, n_ols=2048,
                  epochs=10, k=10, k_prime=128, anns="ivf",
                  ivf=IVFBackendConfig(nprobe=16))
retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0),
                                 verbose=True)

ladder = BucketLadder((8, 16, 32), max_batch=args.max_batch)
queries = ragged_queries(256, 32, tq_range=(2, 24), seed=1)
print(f"ladder: Tq buckets {ladder.tq_ladder}, batch sizes "
      f"{ladder.batch_sizes()}, compile bound {ladder.compile_bound()}")

with RetrieverServer(retriever, ladder=ladder,
                     max_wait_us=args.max_wait_us) as server:
    warm_buckets(retriever, ladder, 32)
    print(f"warmed {server.trace_count()} bucketed shapes "
          f"(<= bound {ladder.compile_bound()})")

    # phase 1: steady-state Poisson traffic
    _, report = replay(server, queries,
                       poisson_trace(args.rate, args.duration, seed=2))
    print(f"steady:   p50={report['p50_ms']:.2f}ms p95={report['p95_ms']:.2f}ms "
          f"p99={report['p99_ms']:.2f}ms  qps={report['qps']:.0f} "
          f"(offered {report['offered_qps']:.0f})  "
          f"occupancy={report['mean_occupancy']:.2f}")
    print(f"occupancy histogram (requests per micro-batch): "
          f"{report['occupancy_hist']}")

    # phase 2: streaming add lands mid-traffic
    extra = synthetic.make_corpus(m=64, d=32, avg_tokens=12, max_tokens=16,
                                  seed=9)
    add_fut = server.add(extra.doc_tokens, extra.doc_mask)
    _, report2 = replay(server, queries,
                        poisson_trace(args.rate, 2.0, seed=3))
    new_m = add_fut.result(timeout=300)
    target = extra.doc_tokens[0][extra.doc_mask[0]]
    # exact latent scan with full coverage: the new doc MUST come back top-1
    exact = SearchParams(use_ann=False, k_prime=new_m)
    _, ids = server.search(np.asarray(target), params=exact, timeout=300)
    print(f"add:      corpus {args.m} -> {new_m} docs mid-traffic; "
          f"post-add query retrieves new doc {int(ids[0])} "
          f"({'OK' if ids[0] >= args.m else 'MISSING'})")
    print(f"post-add: p50={report2['p50_ms']:.2f}ms "
          f"p99={report2['p99_ms']:.2f}ms  qps={report2['qps']:.0f}")
    print(f"jit traces total: {server.trace_count()} "
          f"(bound {ladder.compile_bound()} per snapshot)")
