"""Batched serving example: the serving driver with latency percentiles —
any registered first-stage backend vs exact MaxSim on the same corpus,
through the LemurRetriever facade (one compiled query fn per SearchParams).

Doubles as the smoke test for the gather-at-source serving kernels: by
default the fused path serves (``use_fused_gather=True``, the config
default) and the legacy HBM-gather path is timed next to it; pass
``--no-fused-gather`` to serve legacy-only.  The per-query gathered-bytes
estimate shows WHY the fused path wins on TPU — the legacy path
materializes every gathered byte in HBM before any math runs.

A third mode serves the ONE-LAUNCH first stage (``use_one_launch=True``:
ψ-pool + probe scan + top-k' fused into a single kernel on the ivf backend)
and every row prints its per-search ``launches`` breakdown — the one-launch
row must show exactly 1 pre-rerank launch.

  PYTHONPATH=src python examples/serve_batched.py
  PYTHONPATH=src python examples/serve_batched.py --backend muvera
  PYTHONPATH=src python examples/serve_batched.py --no-fused-gather
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LemurConfig, maxsim, recall_at
from repro.data import synthetic
from repro.retriever import (
    IVFBackendConfig,
    IVFSearchParams,
    LemurRetriever,
    SearchParams,
)

p = argparse.ArgumentParser()
p.add_argument("--backend", default="ivf",
               help="first-stage backend (repro.anns.registry name)")
p.add_argument("--no-fused-gather", action="store_true",
               help="serve ONLY the legacy HBM-gather path (skip the fused "
                    "gather-at-source kernels)")
args = p.parse_args()

corpus = synthetic.make_corpus(m=6000, d=32, avg_tokens=12, max_tokens=16, seed=0)
cfg = LemurConfig(d=32, d_prime=128, m_pretrain=512, n_train=8192, n_ols=2048,
                  epochs=15, k=10, k_prime=128, anns=args.backend,
                  ivf=IVFBackendConfig(nprobe=16))
retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0), verbose=True)

idx = retriever.index


def _params(fused: bool, one_launch: bool = False) -> SearchParams:
    backend = None
    if retriever.backend == "ivf":
        backend = IVFSearchParams(use_fused_gather=fused,
                                  use_one_launch=one_launch)
    return SearchParams(use_fused_gather=fused, backend=backend,
                        use_one_launch=one_launch)


def _gathered_bytes_per_query(fused: bool) -> int:
    """HBM bytes the two serving gathers touch PER QUERY: probed IVF lists
    (ids + vecs [+ scales]) and k' candidate token slabs.  The fused path
    streams these once HBM->VMEM; the legacy path also WRITES them back as
    the materialized gather and re-reads them in the scoring op (3 trips)."""
    n = 0
    if retriever.backend == "ivf":
        ann = idx.ann
        nprobe = min(cfg.ivf.nprobe, ann.nlist)
        item = 1 if ann.scales is not None else 4
        per_slot = cfg.d_prime * item + 4 + (4 if ann.scales is not None else 0)
        n += nprobe * ann.capacity * per_slot
    td = idx.doc_tokens.shape[1]
    n += cfg.k_prime * td * (cfg.d * 4 + 4)
    return n if fused else 3 * n


exact = jax.jit(lambda q, m: maxsim.true_topk(q, m, idx.doc_tokens,
                                              idx.doc_mask, cfg.k))
p50 = lambda xs: np.percentile(xs, 50) * 1e3
p99 = lambda xs: np.percentile(xs, 99) * 1e3

# query batches + exact ground truth ONCE (truth depends only on the batch;
# the exact scan is the slowest op here, no reason to repeat it per mode)
batches, lat_exact = [], []
for b in range(8):
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 32, 8,
                                                        seed=200 + b))
    qm = jnp.ones(q.shape[:2], bool)
    t0 = time.perf_counter(); _, truth = exact(q, qm); jax.block_until_ready(truth)
    lat_exact.append(time.perf_counter() - t0)
    batches.append((q, qm, truth))
lat_exact = lat_exact[1:]  # drop the compile batch


def _serve(params):
    lat, recs = [], []
    for q, qm, truth in batches:
        t0 = time.perf_counter()
        s, ids = retriever.search(q, qm, params)
        jax.block_until_ready(ids)
        lat.append(time.perf_counter() - t0)
        recs.append(float(recall_at(ids, truth).mean()))
    return lat[1:], recs[1:]  # drop the compile batch


modes = [(False, False, "legacy")] if args.no_fused_gather else \
        [(True, False, "fused "), (False, False, "legacy"),
         (True, True, "1launch")]
results = {}
for fused, one_launch, label in modes:
    params = _params(fused, one_launch)
    lat, recs = _serve(params)
    results[label] = lat
    est = _gathered_bytes_per_query(fused)
    plan = retriever.launches(params)
    pre = sum(v for name, v in plan.items() if name != "rerank")
    print(f"LEMUR[{retriever.backend}|{label}]: p50={p50(lat):.1f}ms "
          f"p99={p99(lat):.1f}ms / 32-query batch "
          f"(~{est/1e6:.2f} MB gathered/query, "
          f"jit traces: {retriever.trace_count(params)}, "
          f"launches: {plan} = {pre} pre-rerank)  "
          f"recall@10={np.mean(recs):.3f}")

print(f"exact : p50={p50(lat_exact):.1f}ms p99={p99(lat_exact):.1f}ms")
base = results.get("legacy", next(iter(results.values())))
print(f"speedup vs exact x{np.mean(lat_exact)/np.mean(base):.1f}")
if len(results) == 2:
    print(f"fused vs legacy x{np.mean(results['legacy'])/np.mean(results['fused ']):.2f}")
