"""Batched serving example: the serving driver with latency percentiles —
any registered first-stage backend vs exact MaxSim on the same corpus,
through the LemurRetriever facade (one compiled query fn per SearchParams).

  PYTHONPATH=src python examples/serve_batched.py
  PYTHONPATH=src python examples/serve_batched.py --backend muvera
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LemurConfig, maxsim, recall_at
from repro.data import synthetic
from repro.retriever import IVFBackendConfig, LemurRetriever, SearchParams

p = argparse.ArgumentParser()
p.add_argument("--backend", default="ivf",
               help="first-stage backend (repro.anns.registry name)")
args = p.parse_args()

corpus = synthetic.make_corpus(m=6000, d=32, avg_tokens=12, max_tokens=16, seed=0)
cfg = LemurConfig(d=32, d_prime=128, m_pretrain=512, n_train=8192, n_ols=2048,
                  epochs=15, k=10, k_prime=128, anns=args.backend,
                  ivf=IVFBackendConfig(nprobe=16))
retriever = LemurRetriever.build(corpus, cfg, key=jax.random.PRNGKey(0), verbose=True)

idx = retriever.index
params = SearchParams()  # cfg defaults: k=10, k'=128, backend namespace knobs
exact = jax.jit(lambda q, m: maxsim.true_topk(q, m, idx.doc_tokens,
                                              idx.doc_mask, cfg.k))

lat_lemur, lat_exact, recs = [], [], []
for b in range(8):
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 32, 8, seed=200 + b))
    qm = jnp.ones(q.shape[:2], bool)
    t0 = time.perf_counter()
    s, ids = retriever.search(q, qm, params)
    jax.block_until_ready(ids)
    lat_lemur.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); _, truth = exact(q, qm); jax.block_until_ready(truth)
    lat_exact.append(time.perf_counter() - t0)
    recs.append(float(recall_at(ids, truth).mean()))

lat_lemur, lat_exact, recs = lat_lemur[1:], lat_exact[1:], recs[1:]  # drop compile batch
p50 = lambda xs: np.percentile(xs, 50) * 1e3
p99 = lambda xs: np.percentile(xs, 99) * 1e3
print(f"LEMUR[{retriever.backend}]: p50={p50(lat_lemur):.1f}ms "
      f"p99={p99(lat_lemur):.1f}ms / 32-query batch "
      f"(jit traces: {retriever.trace_count(params)})")
print(f"exact : p50={p50(lat_exact):.1f}ms p99={p99(lat_exact):.1f}ms")
print(f"recall@10 = {np.mean(recs):.3f}  speedup x{np.mean(lat_exact)/np.mean(lat_lemur):.1f}")
