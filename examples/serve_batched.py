"""Batched serving example: the serving driver with latency percentiles —
any registered first-stage backend vs exact MaxSim on the same corpus.

  PYTHONPATH=src python examples/serve_batched.py
  PYTHONPATH=src python examples/serve_batched.py --backend muvera
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LemurConfig, build_index, maxsim, recall_at
from repro.core.index import query
from repro.data import synthetic

p = argparse.ArgumentParser()
p.add_argument("--backend", default="ivf",
               help="first-stage backend (repro.anns.registry name)")
args = p.parse_args()

corpus = synthetic.make_corpus(m=6000, d=32, avg_tokens=12, max_tokens=16, seed=0)
cfg = LemurConfig(d=32, d_prime=128, m_pretrain=512, n_train=8192, n_ols=2048,
                  epochs=15, k=10, k_prime=128, anns=args.backend, ivf_nprobe=16)
index = build_index(jax.random.PRNGKey(0), corpus, cfg, verbose=True)

serve = jax.jit(lambda q, m: query(index, q, m))
exact = jax.jit(lambda q, m: maxsim.true_topk(q, m, index.doc_tokens,
                                              index.doc_mask, cfg.k))

lat_lemur, lat_exact, recs = [], [], []
for b in range(8):
    q = jnp.asarray(synthetic.queries_from_corpus_query(corpus, 32, 8, seed=200 + b))
    qm = jnp.ones(q.shape[:2], bool)
    t0 = time.perf_counter(); s, ids = serve(q, qm); jax.block_until_ready(ids)
    lat_lemur.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); _, truth = exact(q, qm); jax.block_until_ready(truth)
    lat_exact.append(time.perf_counter() - t0)
    recs.append(float(recall_at(ids, truth).mean()))

lat_lemur, lat_exact = lat_lemur[1:], lat_exact[1:]  # drop compile batch
p50 = lambda xs: np.percentile(xs, 50) * 1e3
p99 = lambda xs: np.percentile(xs, 99) * 1e3
print(f"LEMUR[{index.backend}]: p50={p50(lat_lemur):.1f}ms "
      f"p99={p99(lat_lemur):.1f}ms / 32-query batch")
print(f"exact : p50={p50(lat_exact):.1f}ms p99={p99(lat_exact):.1f}ms")
print(f"recall@10 = {np.mean(recs):.3f}  speedup x{np.mean(lat_exact)/np.mean(lat_lemur):.1f}")
